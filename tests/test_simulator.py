"""Discrete-event simulator + batch scheduler tests (SS5)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.bound import max_stretch_lower_bound, stretch_feasible
from repro.core.job import JobSpec
from repro.sched.batch import batch_schedule
from repro.sched.cluster import ClusterEvent
from repro.sched.simulator import DFRSSimulator, SimParams, simulate
from repro.workloads.lublin import lublin_trace, offered_load, scale_to_load


def mini_trace(n=40, nodes=16, seed=0):
    return lublin_trace(n_jobs=n, n_nodes=nodes, seed=seed)


# --------------------------------------------------------------------------- #
# conservation / correctness invariants                                        #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", [
    "GreedyP */OPT=MIN",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "MCB8/per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
])
def test_all_jobs_complete_and_bound_holds(policy):
    specs = mini_trace()
    params = SimParams(n_nodes=16)
    r = simulate(specs, policy, params)
    assert set(r.completions) == {s.jid for s in specs}
    lb = max_stretch_lower_bound(specs, 16)
    assert r.max_stretch >= lb - 1e-6
    # completion after release + dedicated time
    for s in specs:
        assert r.completions[s.jid] >= s.release + s.proc_time - 1e-6


def test_single_job_runs_dedicated():
    """One job alone on the cluster: stretch == 1 (bounded formula)."""
    s = JobSpec(jid=0, release=0.0, proc_time=1000.0, n_tasks=4,
                cpu_need=1.0, mem_req=0.5)
    r = simulate([s], "GreedyP */OPT=MIN", SimParams(n_nodes=8))
    assert r.completions[0] == pytest.approx(1000.0)
    assert r.max_stretch == pytest.approx(1.0)
    assert r.n_pmtn == 0 and r.n_mig == 0


def test_cpu_oversubscription_slows_proportionally():
    """Two 1-node cpu-1.0 jobs on one node: equal shares, both 2x slower."""
    specs = [JobSpec(jid=i, release=0.0, proc_time=100.0, n_tasks=1,
                     cpu_need=1.0, mem_req=0.4) for i in range(2)]
    r = simulate(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=1))
    for jid in (0, 1):
        assert r.completions[jid] == pytest.approx(200.0)


def test_memory_constraint_forces_queueing():
    """Two mem-0.6 jobs cannot share one node: sequential execution."""
    specs = [JobSpec(jid=i, release=0.0, proc_time=100.0, n_tasks=1,
                     cpu_need=0.5, mem_req=0.6) for i in range(2)]
    r = simulate(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=1, penalty=0.0))
    times = sorted(r.completions.values())
    assert times[0] == pytest.approx(100.0)
    assert times[1] >= 200.0 - 1e-6


def test_rescheduling_penalty_applied_on_resume():
    """A paused+resumed job must lose at least one penalty of progress."""
    p = SimParams(n_nodes=1, penalty=300.0)
    long_job = JobSpec(jid=0, release=0.0, proc_time=5000.0, n_tasks=1,
                       cpu_need=1.0, mem_req=0.8)
    short = JobSpec(jid=1, release=100.0, proc_time=50.0, n_tasks=1,
                    cpu_need=1.0, mem_req=0.8)
    r = simulate([long_job, short], "GreedyP */OPT=MIN", p)
    # long job: 5000 work + 50 preempted window + >=300 penalty
    assert r.completions[0] >= 5000.0 + 50.0 + 300.0 - 1e-6
    assert r.n_pmtn >= 1


def test_placement_continues_while_nodes_down():
    """Regression: placing jobs on healthy nodes must work while other
    nodes are marked failed (the dead-node sentinel must not trip the
    pool's global memory invariant)."""
    specs = [JobSpec(jid=i, release=float(i * 10), proc_time=50.0, n_tasks=1,
                     cpu_need=0.5, mem_req=0.2) for i in range(6)]
    ev = [ClusterEvent(time=5.0, kind="fail", nodes=(0, 1))]
    r = simulate(specs, "GreedyPM */per/OPT=MIN/MINVT=600",
                 SimParams(n_nodes=4), cluster_events=ev)
    assert set(r.completions) == {s.jid for s in specs}


def test_node_failure_forces_preemption_and_recovery():
    specs = [JobSpec(jid=0, release=0.0, proc_time=1000.0, n_tasks=2,
                     cpu_need=1.0, mem_req=0.5)]
    ev = [ClusterEvent(time=100.0, kind="fail", nodes=(0,)),
          ClusterEvent(time=400.0, kind="join", nodes=(0,))]
    r = simulate(specs, "GreedyP */per/OPT=MIN", SimParams(n_nodes=2),
                 cluster_events=ev)
    assert r.completions[0] >= 1000.0 + 300.0 - 1e-6   # penalty paid
    assert r.n_pmtn >= 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_underutilization_nonnegative(seed):
    specs = mini_trace(n=25, seed=seed)
    r = simulate(specs, "GreedyPM */per/OPT=MIN/MINVT=600", SimParams(n_nodes=16))
    assert r.underutilization >= -1e-6


# --------------------------------------------------------------------------- #
# batch schedulers                                                             #
# --------------------------------------------------------------------------- #
def test_fcfs_order_and_exclusivity():
    specs = [
        JobSpec(jid=0, release=0.0, proc_time=100.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),
        JobSpec(jid=1, release=1.0, proc_time=10.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),
    ]
    r = batch_schedule(specs, "FCFS", SimParams(n_nodes=2))
    assert r.completions[0] == pytest.approx(100.0)
    assert r.completions[1] == pytest.approx(110.0)   # waits for both nodes


def test_easy_backfills_small_jobs():
    """A short 1-node job backfills ahead of a blocked wide job."""
    specs = [
        JobSpec(jid=0, release=0.0, proc_time=100.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),  # runs
        JobSpec(jid=1, release=1.0, proc_time=50.0, n_tasks=3, cpu_need=1.0, mem_req=0.5),   # blocked head
        JobSpec(jid=2, release=2.0, proc_time=20.0, n_tasks=1, cpu_need=1.0, mem_req=0.5),   # backfill
    ]
    fcfs = batch_schedule(specs, "FCFS", SimParams(n_nodes=3))
    easy = batch_schedule(specs, "EASY", SimParams(n_nodes=3))
    assert easy.completions[2] < fcfs.completions[2]   # backfilled earlier
    assert easy.completions[1] <= fcfs.completions[1] + 1e-9  # reservation kept


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 50))
def test_easy_never_worse_than_fcfs_makespan(seed):
    specs = mini_trace(n=30, seed=seed)
    f = batch_schedule(specs, "FCFS", SimParams(n_nodes=16))
    e = batch_schedule(specs, "EASY", SimParams(n_nodes=16))
    assert set(e.completions) == {s.jid for s in specs}
    assert e.makespan <= f.makespan + 1e-6


# --------------------------------------------------------------------------- #
# bound (Theorem 1)                                                            #
# --------------------------------------------------------------------------- #
def test_bound_exact_tiny_case():
    """Two equal jobs on one node released together: optimal max stretch 2.

    Each p=100, c=1 (tau=10 does not bind).  At S=1.5 the common deadline is
    150 but total work is 200 > capacity -> infeasible; S=2 is feasible
    (both finish by 200).
    """
    specs = [JobSpec(jid=i, release=0.0, proc_time=100.0, n_tasks=1,
                     cpu_need=1.0, mem_req=0.1) for i in range(2)]
    assert not stretch_feasible(specs, 1, 1.5)
    assert stretch_feasible(specs, 1, 2.0)
    lb = max_stretch_lower_bound(specs, 1, rtol=1e-3)
    assert lb == pytest.approx(2.0, abs=2e-2)


def test_bound_tau_floor():
    """Bounded stretch (tau=10): short jobs floor the bound at tau/p_min."""
    specs = [JobSpec(jid=0, release=0.0, proc_time=1.0, n_tasks=1,
                     cpu_need=1.0, mem_req=0.1)]
    assert max_stretch_lower_bound(specs, 4) == pytest.approx(10.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_bound_feasibility_monotone_in_stretch(seed):
    specs = mini_trace(n=15, seed=seed)
    lb = max_stretch_lower_bound(specs, 16)
    assert stretch_feasible(specs, 16, lb * 2 + 1.0)
    # below the bound must be infeasible — unless the bound IS the tau floor
    # (tau/p_min), which is a bounded-stretch constraint, not a flow one.
    s_lo = max(1.0, 10.0 / min(s.proc_time for s in specs))
    if lb > s_lo * 1.05:
        assert not stretch_feasible(specs, 16, lb * 0.9)


def test_offered_load_scaling():
    specs = mini_trace(n=60, nodes=16, seed=3)
    scaled = scale_to_load(specs, 16, 0.5)
    assert offered_load(scaled, 16) == pytest.approx(0.5, rel=1e-6)
    # same job mix, shifted releases only
    assert [s.proc_time for s in scaled] == [s.proc_time for s in sorted(specs, key=lambda x: x.release)]
