"""Unified-engine tests: wrapper equivalence, transactional migration,
max_events bounding, and batch/DFRS behaviour through the one event loop.

Unlike test_simulator.py these tests do not need hypothesis, so they run
even on minimal installs — they carry the core engine invariants.
"""
import math

import numpy as np
import pytest

from repro.core.bound import max_stretch_lower_bound
from repro.core.job import JobSpec
from repro.core.state import S_PENDING
from repro.sched.batch import batch_schedule
from repro.sched.cluster import ClusterEvent
from repro.sched.engine import Engine, SimParams
from repro.sched.simulator import DFRSSimulator, simulate
from repro.workloads.lublin import lublin_trace


def mini_trace(n=40, nodes=16, seed=0):
    return lublin_trace(n_jobs=n, n_nodes=nodes, seed=seed)


# --------------------------------------------------------------------------- #
# equivalence: every public entry point is the same engine                      #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", [
    "GreedyP */OPT=MIN",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
])
def test_simulate_equals_engine_on_seeded_lublin(policy):
    """Old simulate() front-end vs direct Engine: identical completions and
    stretch metrics (the refactor's bit-for-bit contract)."""
    specs = mini_trace()
    params = SimParams(n_nodes=16)
    a = simulate(specs, policy, params)
    b = Engine(specs, policy, SimParams(n_nodes=16)).run()
    c = DFRSSimulator(specs, policy, SimParams(n_nodes=16)).run()
    assert a.completions == b.completions == c.completions
    assert a.stretches == b.stretches == c.stretches
    assert a.max_stretch == b.max_stretch == c.max_stretch
    assert (a.n_pmtn, a.n_mig) == (b.n_pmtn, b.n_mig) == (c.n_pmtn, c.n_mig)


@pytest.mark.parametrize("algo", ["FCFS", "EASY"])
def test_batch_entrypoints_agree(algo):
    specs = mini_trace(n=30)
    a = batch_schedule(specs, algo, SimParams(n_nodes=16))
    b = simulate(specs, algo, SimParams(n_nodes=16))
    c = Engine(specs, algo, SimParams(n_nodes=16)).run()
    assert a.completions == b.completions == c.completions
    assert a.policy == algo


def test_dfrs_simulator_rejects_batch():
    with pytest.raises(ValueError):
        DFRSSimulator(mini_trace(n=5), "FCFS")
    with pytest.raises(ValueError):
        batch_schedule(mini_trace(n=5), "GreedyP */OPT=MIN")


# --------------------------------------------------------------------------- #
# conservation / fluid-model invariants (engine-native, no hypothesis)          #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", [
    "GreedyP */OPT=MIN",
    "MCB8/per/OPT=MIN/MINVT=600",
    "/stretch-per/OPT=MAX",
    "FCFS",
    "EASY",
])
def test_all_jobs_complete_and_bound_holds(policy):
    specs = mini_trace()
    r = simulate(specs, policy, SimParams(n_nodes=16))
    assert set(r.completions) == {s.jid for s in specs}
    lb = max_stretch_lower_bound(specs, 16)
    assert r.max_stretch >= lb - 1e-6
    for s in specs:
        assert r.completions[s.jid] >= s.release + s.proc_time - 1e-6
    assert r.underutilization >= -1e-6


def test_single_job_runs_dedicated():
    s = JobSpec(jid=0, release=0.0, proc_time=1000.0, n_tasks=4,
                cpu_need=1.0, mem_req=0.5)
    r = simulate([s], "GreedyP */OPT=MIN", SimParams(n_nodes=8))
    assert r.completions[0] == pytest.approx(1000.0)
    assert r.max_stretch == pytest.approx(1.0)
    assert r.n_pmtn == 0 and r.n_mig == 0


def test_cpu_oversubscription_slows_proportionally():
    specs = [JobSpec(jid=i, release=0.0, proc_time=100.0, n_tasks=1,
                     cpu_need=1.0, mem_req=0.4) for i in range(2)]
    r = simulate(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=1))
    for jid in (0, 1):
        assert r.completions[jid] == pytest.approx(200.0)


def test_rescheduling_penalty_applied_on_resume():
    p = SimParams(n_nodes=1, penalty=300.0)
    long_job = JobSpec(jid=0, release=0.0, proc_time=5000.0, n_tasks=1,
                       cpu_need=1.0, mem_req=0.8)
    short = JobSpec(jid=1, release=100.0, proc_time=50.0, n_tasks=1,
                    cpu_need=1.0, mem_req=0.8)
    r = simulate([long_job, short], "GreedyP */OPT=MIN", p)
    assert r.completions[0] >= 5000.0 + 50.0 + 300.0 - 1e-6
    assert r.n_pmtn >= 1


def test_fcfs_order_and_exclusivity():
    specs = [
        JobSpec(jid=0, release=0.0, proc_time=100.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),
        JobSpec(jid=1, release=1.0, proc_time=10.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),
    ]
    r = batch_schedule(specs, "FCFS", SimParams(n_nodes=2))
    assert r.completions[0] == pytest.approx(100.0)
    assert r.completions[1] == pytest.approx(110.0)   # waits for both nodes


def test_easy_backfills_small_jobs():
    specs = [
        JobSpec(jid=0, release=0.0, proc_time=100.0, n_tasks=2, cpu_need=1.0, mem_req=0.5),
        JobSpec(jid=1, release=1.0, proc_time=50.0, n_tasks=3, cpu_need=1.0, mem_req=0.5),
        JobSpec(jid=2, release=2.0, proc_time=20.0, n_tasks=1, cpu_need=1.0, mem_req=0.5),
    ]
    fcfs = batch_schedule(specs, "FCFS", SimParams(n_nodes=3))
    easy = batch_schedule(specs, "EASY", SimParams(n_nodes=3))
    assert easy.completions[2] < fcfs.completions[2]   # backfilled earlier
    assert easy.completions[1] <= fcfs.completions[1] + 1e-9


def test_node_failure_forces_preemption_and_recovery():
    specs = [JobSpec(jid=0, release=0.0, proc_time=1000.0, n_tasks=2,
                     cpu_need=1.0, mem_req=0.5)]
    ev = [ClusterEvent(time=100.0, kind="fail", nodes=(0,)),
          ClusterEvent(time=400.0, kind="join", nodes=(0,))]
    r = simulate(specs, "GreedyP */per/OPT=MIN", SimParams(n_nodes=2),
                 cluster_events=ev)
    assert r.completions[0] >= 1000.0 + 300.0 - 1e-6
    assert r.n_pmtn >= 1


# --------------------------------------------------------------------------- #
# transactional multi-job migration                                             #
# --------------------------------------------------------------------------- #
def _engine_with_running_pair():
    """Two running mem-0.6 jobs on a 2-node cluster, one node each."""
    specs = [JobSpec(jid=i, release=0.0, proc_time=100.0, n_tasks=1,
                     cpu_need=1.0, mem_req=0.6) for i in range(2)]
    e = Engine(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=2, penalty=300.0))
    st = e.state
    st.status[:] = S_PENDING
    e.start(st.views[0], [0])
    e.start(st.views[1], [1])
    return e


def test_migrate_many_feasible_only_as_a_set():
    """Regression: swapping two mem-0.6 jobs between two nodes is only
    feasible transactionally — placing either job on its target before the
    other is removed would oversubscribe node memory.  All removals must
    happen before any placement."""
    e = _engine_with_running_pair()
    v0, v1 = e.state.views[0], e.state.views[1]
    e.migrate_many([(v0, [1]), (v1, [0])])     # must not raise
    assert v0.mapping == [1] and v1.mapping == [0]
    assert e.n_mig == 2
    # both paid the rescheduling penalty
    assert v0.penalty_until == pytest.approx(e.state.now + 300.0)
    assert v1.penalty_until == pytest.approx(e.state.now + 300.0)
    # pool is consistent: one 0.6 image per node
    np.testing.assert_allclose(e.state.pool.mem_free, [0.4, 0.4])
    # a non-transactional (place-before-remove) apply would have raised:
    with pytest.raises(RuntimeError):
        e.state.pool.place(v0.spec, [0])       # oversubscribes node 0
    e.state.pool.remove(v0.spec, [0])


def test_migrate_many_no_move_is_free():
    """A 'migration' to the same node multiset costs nothing."""
    e = _engine_with_running_pair()
    v0 = e.state.views[0]
    e.migrate_many([(v0, [0])])
    assert e.n_mig == 0 and e.bytes_moved_gb == pytest.approx(0.0)
    assert v0.penalty_until == -math.inf


# --------------------------------------------------------------------------- #
# max_events bounding                                                           #
# --------------------------------------------------------------------------- #
def test_max_events_raises_with_clear_error():
    specs = mini_trace(n=20)
    with pytest.raises(RuntimeError, match="max_events=5"):
        simulate(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=16, max_events=5))
    with pytest.raises(RuntimeError, match="event budget"):
        simulate(specs, "FCFS", SimParams(n_nodes=16, max_events=5))


def test_max_events_truncate_surfaces_cap_in_result():
    specs = mini_trace(n=20)
    p = SimParams(n_nodes=16, max_events=5, on_max_events="truncate")
    r = simulate(specs, "GreedyP */OPT=MIN", p)
    assert r.hit_max_events
    assert r.events == 5
    # partial: some jobs cannot have completed in 5 events
    assert len(r.completions) < len(specs)
    # untruncated runs are flagged healthy
    full = simulate(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=16))
    assert not full.hit_max_events
    assert set(full.completions) == {s.jid for s in specs}


def test_sim_params_validation():
    with pytest.raises(ValueError):
        SimParams(max_events=0)
    with pytest.raises(ValueError):
        SimParams(on_max_events="explode")
