"""Streaming-session tests: the open SimSession step/ingest API.

* split-run bit-identity: stepping the session to exhaustion through
  arbitrary ``step_until``/``step`` boundary schedules produces a
  ``SimResult`` identical to ``Engine.run()`` — on the golden acceptance
  grid, on every Table-1 policy, and (with hypothesis) on random
  boundaries;
* snapshot round-trips: mid-run snapshot → JSON on disk → restore (same
  and *fresh* process) → identical final result, CSR incidence included;
* online ingest: mid-run submits, live fail/join/period injection,
  duplicate/past-release validation;
* what-if branching: same-policy forks continue bit-identically, switched
  forks adopt the live state (``sweep.run_branches`` records);
* reactive scenarios, the streaming CLI, and the compat-shim pointer.
"""
import dataclasses

from conftest import result_dict as _result_dict
import json
import math
import os
import subprocess
import sys
import warnings

import pytest

from repro import api
from repro.__main__ import main as cli_main
from repro.core.policies import TABLE1_POLICIES
from repro.sched import _compat
from repro.sched.engine import Engine, SimParams
from repro.sched.scenarios import apply_scenario, run_reactive
from repro.sched.session import SessionState, SimSession, open_session
from repro.sched.sweep import run_branches
from repro.workloads.registry import WorkloadSpec, make_trace

W_SMALL = WorkloadSpec("lublin", n_jobs=25, n_nodes=16, seed=0)


def _cell(workload, policy, scenario="baseline"):
    specs = make_trace(workload)
    specs, events = apply_scenario(scenario, specs, workload.n_nodes,
                                   seed=workload.seed)
    params = SimParams(n_nodes=workload.n_nodes)
    return specs, events, params


def _session_for(specs, policy, params, events):
    return SimSession.from_engine(
        Engine(specs, policy, params, cluster_events=events))


# three distinct step-boundary schedules (the acceptance criterion)
def _schedule_halves(ses, ref):
    t0 = ref.final_time - ref.makespan
    ses.step_until(t0 + 0.5 * ref.makespan)


def _schedule_deciles(ses, ref):
    t0 = ref.final_time - ref.makespan
    for f in range(1, 10):
        ses.step_until(t0 + 0.1 * f * ref.makespan)


def _schedule_event_steps(ses, ref):
    while ses.step(5):
        pass


SCHEDULES = [_schedule_halves, _schedule_deciles, _schedule_event_steps]


# --------------------------------------------------------------------------- #
# split-run bit-identity                                                       #
# --------------------------------------------------------------------------- #
GOLDEN_POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
                   "GreedyPM */per/OPT=MIN/MINVT=600"]
GOLDEN_WORKLOADS = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
                    WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]
GOLDEN_CASES = [(w, p, sc)
                for w in GOLDEN_WORKLOADS
                for p in GOLDEN_POLICIES
                for sc in ("baseline", "rack_failure")]
GOLDEN_CASES.append((GOLDEN_WORKLOADS[0], "/stretch-per/OPT=MAX", "baseline"))


@pytest.mark.parametrize(
    "i,workload,policy,scenario",
    [(i, w, p, sc) for i, (w, p, sc) in enumerate(GOLDEN_CASES)],
    ids=[f"{w.name}-{p}-{sc}" for w, p, sc in GOLDEN_CASES])
def test_golden_grid_split_run_bit_identical(i, workload, policy, scenario):
    """Each golden cell, stepped through one of the three boundary
    schedules (rotating), matches the unsplit Engine.run() bit for bit."""
    specs, events, params = _cell(workload, policy, scenario)
    ref = Engine(specs, policy, params, cluster_events=events).run()
    ses = _session_for(specs, policy, params, events)
    SCHEDULES[i % len(SCHEDULES)](ses, ref)
    assert _result_dict(ses.run()) == _result_dict(ref)


_TABLE1_REF = {}


@pytest.mark.parametrize("policy", TABLE1_POLICIES + ["FCFS", "EASY"])
@pytest.mark.parametrize("schedule", SCHEDULES,
                         ids=["halves", "deciles", "event-steps"])
def test_every_table1_policy_split_run_bit_identical(policy, schedule):
    specs, events, params = _cell(W_SMALL, policy)
    if policy not in _TABLE1_REF:
        _TABLE1_REF[policy] = Engine(specs, policy, params,
                                     cluster_events=events).run()
    ref = _TABLE1_REF[policy]
    ses = _session_for(specs, policy, params, events)
    schedule(ses, ref)
    assert _result_dict(ses.run()) == _result_dict(ref)


def test_step_boundaries_do_not_advance_the_engine_clock():
    """step_until(t) between events must not advance the fluid integrals
    to t (that would split advance() windows and break bit-identity); the
    session clock reads t, the engine clock stays on the last event."""
    specs, events, params = _cell(W_SMALL, "FCFS")
    ses = _session_for(specs, "FCFS", params, events)
    ses.step_until(specs[0].release + 1.0)   # mid-gap boundary
    assert ses.now == specs[0].release + 1.0
    assert ses.engine.state.now <= specs[0].release + 1.0
    assert ses.engine.state.now in [s.release for s in specs] + [0.0]


# hypothesis: arbitrary random boundary schedules
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _REF = {}

    def _ref(policy):
        if policy not in _REF:
            specs, events, params = _cell(W_SMALL, policy, "rack_failure")
            _REF[policy] = (specs, events, params,
                            Engine(specs, policy, params,
                                   cluster_events=events).run())
        return _REF[policy]

    @settings(max_examples=25, deadline=None)
    @given(
        policy=st.sampled_from(["GreedyP */OPT=MIN", "EASY",
                                "Greedy */per/OPT=MIN"]),
        cuts=st.lists(st.floats(min_value=0.0, max_value=1.3,
                                allow_nan=False), max_size=8),
        n_step=st.integers(min_value=1, max_value=9),
    )
    def test_random_split_schedules_bit_identical(policy, cuts, n_step):
        specs, events, params, ref = _ref(policy)
        t0 = ref.final_time - ref.makespan
        ses = _session_for(specs, policy, params, events)
        for f in sorted(cuts):
            ses.step_until(t0 + f * ref.makespan)
        ses.step(n_step)
        assert _result_dict(ses.run()) == _result_dict(ref)
else:                                    # pragma: no cover
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_random_split_schedules_bit_identical():
        pass


# --------------------------------------------------------------------------- #
# snapshot / restore                                                           #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", ["FCFS", "EASY", "GreedyP */OPT=MIN",
                                    "GreedyPM */per/OPT=MIN/MINVT=600",
                                    "/stretch-per/OPT=MAX", "EASY+OPT=MIN"])
def test_snapshot_json_roundtrip_restores_bit_identically(policy, tmp_path):
    specs, events, params = _cell(W_SMALL, policy, "rack_failure")
    ref = Engine(specs, policy, params, cluster_events=events).run()
    ses = _session_for(specs, policy, params, events)
    ses.step_until(specs[0].release + 0.4 * ref.makespan)
    snap = ses.snapshot()
    path = str(tmp_path / "snap.json")
    snap.save(path)
    loaded = SessionState.load(path)
    assert loaded.fingerprint == snap.fingerprint
    assert _result_dict(SimSession.restore(loaded).run()) == _result_dict(ref)
    # the un-snapshotted session continues identically too
    assert _result_dict(ses.run()) == _result_dict(ref)


def test_snapshot_restore_in_fresh_process(tmp_path):
    """Serialize a mid-run snapshot to disk, finish it in a *fresh*
    interpreter, and require the final SimResult (CSR-incidence-dependent
    yields included) to match the straight-through run exactly."""
    policy = "GreedyPM */per/OPT=MIN/MINVT=600"
    specs, events, params = _cell(W_SMALL, policy, "rack_failure")
    ref = Engine(specs, policy, params, cluster_events=events).run()
    ses = _session_for(specs, policy, params, events)
    ses.step_until(specs[0].release + 0.5 * ref.makespan)
    path = str(tmp_path / "snap.json")
    ses.snapshot().save(path)
    prog = (
        "import dataclasses, json, sys\n"
        "from repro.sched.session import SimSession\n"
        "r = SimSession.restore(sys.argv[1]).run()\n"
        "d = dataclasses.asdict(r)\n"
        "d.pop('sim_wall_s')\n"
        "print(json.dumps(d))\n"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", prog, path],
        capture_output=True, text=True, check=True, env=env)
    fresh = json.loads(out.stdout)
    want = json.loads(json.dumps(_result_dict(ref)))   # str-keyed dicts
    assert fresh == want


def test_snapshot_fingerprint_detects_corruption(tmp_path):
    specs, events, params = _cell(W_SMALL, "FCFS")
    ses = _session_for(specs, "FCFS", params, events)
    ses.step(3)
    payload = ses.snapshot().to_json_dict()
    payload["now"] = payload["now"] + 1.0
    with pytest.raises(ValueError, match="fingerprint"):
        SessionState.from_json_dict(payload)
    with pytest.raises(ValueError, match="snapshot"):
        SessionState({"schema": "not-a-session"})


def test_snapshot_refuses_anonymous_policy_without_override():
    from repro.sched.components import OptMin, QueueSubmit, ReclaimNodes
    from repro.sched.components import FCFSStart, compose
    pol = compose("ad-hoc", QueueSubmit(), ReclaimNodes(), FCFSStart(),
                  OptMin())
    specs, events, params = _cell(W_SMALL, "FCFS")
    ses = _session_for(specs, pol, params, events)
    ses.step(3)
    snap = ses.snapshot()
    assert snap.policy is None
    with pytest.raises(ValueError, match="policy="):
        SimSession.restore(snap)
    r = SimSession.restore(snap, policy="FCFS").run()
    assert len(r.completions) == 25


# --------------------------------------------------------------------------- #
# lifecycle: close, hooks, context manager                                     #
# --------------------------------------------------------------------------- #
def test_close_is_idempotent_and_runs_hooks_once():
    ses = open_session(16, "FCFS")
    calls = []
    ses.add_close_hook(lambda s: calls.append(s))
    assert not ses.closed
    ses.close()
    ses.close()                     # idempotent: hooks don't re-run
    assert ses.closed
    assert calls == [ses]


def test_context_manager_closes_and_refuses_reentry():
    with open_session(16, "FCFS") as ses:
        ses.submit(make_trace(W_SMALL))
        ses.run_to_exhaustion()
    assert ses.closed
    with pytest.raises(ValueError, match="closed"):
        with ses:
            pass


def test_ops_after_close_raise_reads_still_work():
    ses = open_session(16, "FCFS")
    ses.submit(make_trace(W_SMALL))
    ses.run_to_exhaustion()
    ses.close()
    for call in (lambda: ses.submit(make_trace(W_SMALL)),
                 lambda: ses.inject({"kind": "fail", "t": 1.0,
                                     "nodes": [0]}),
                 lambda: ses.step_until(1e9),
                 lambda: ses.step(),
                 lambda: ses.run_to_exhaustion(),
                 lambda: ses.set_period(600.0),
                 lambda: ses.snapshot()):
        with pytest.raises(ValueError, match="closed"):
            call()
    # a holder can still collect metrics from a closed session
    assert ses.observe()["exhausted"]
    assert len(ses.result().completions) == 25


def test_close_hook_registered_after_close_runs_immediately():
    ses = open_session(16, "FCFS")
    ses.close()
    calls = []
    ses.add_close_hook(lambda s: calls.append(s))
    assert calls == [ses]


def test_close_hook_errors_propagate_but_every_hook_runs():
    ses = open_session(16, "FCFS")
    calls = []

    def bad(_):
        raise RuntimeError("hook boom")

    ses.add_close_hook(bad)
    ses.add_close_hook(lambda s: calls.append("ran"))
    with pytest.raises(RuntimeError, match="hook boom"):
        ses.close()
    assert calls == ["ran"]         # the later hook still ran
    assert ses.closed               # and the session is closed regardless


# --------------------------------------------------------------------------- #
# snapshot schema versioning                                                   #
# --------------------------------------------------------------------------- #
def test_snapshot_carries_version_and_round_trips():
    from repro.sched.session import SNAPSHOT_VERSION
    specs, events, params = _cell(W_SMALL, "FCFS")
    ses = _session_for(specs, "FCFS", params, events)
    ses.step(3)
    snap = ses.snapshot()
    assert snap.payload["version"] == SNAPSHOT_VERSION
    ref = Engine(specs, "FCFS", params, cluster_events=events).run()
    assert _result_dict(SimSession.restore(snap).run()) == _result_dict(ref)
    # pre-versioning (v1) snapshots carry no version key and still restore
    legacy = ses.snapshot()
    del legacy.payload["version"]
    assert _result_dict(SimSession.restore(legacy).run()) \
        == _result_dict(ref)


def test_snapshot_version_mismatch_is_a_clear_error():
    ses = open_session(16, "FCFS")
    snap = ses.snapshot()
    snap.payload["version"] = 99
    with pytest.raises(ValueError, match="version 99 is not supported"):
        SimSession.restore(snap)


def test_snapshot_missing_key_is_a_clear_error():
    """A truncated/foreign payload used to die with an opaque KeyError
    deep in restore; now it's a ValueError naming the missing keys."""
    ses = open_session(16, "FCFS")
    snap = ses.snapshot()
    del snap.payload["vt"]
    del snap.payload["mappings"]
    with pytest.raises(ValueError,
                       match=r"missing required keys \['mappings', 'vt'\]"):
        SimSession.restore(snap)


# --------------------------------------------------------------------------- #
# online ingest: submit / inject                                               #
# --------------------------------------------------------------------------- #
def test_open_session_submit_then_run_equals_engine_run():
    """The streaming path (open → submit → exhaust) is the same simulation
    as the closed-world constructor, periodic tick arming included."""
    specs = make_trace(W_SMALL)
    for policy in ["Greedy */per/OPT=MIN", "EASY"]:
        ref = Engine(specs, policy, SimParams(n_nodes=16)).run()
        ses = open_session(16, policy)
        ses.submit(specs)
        assert _result_dict(ses.run()) == _result_dict(ref)


def test_mid_run_submit_is_a_true_online_arrival():
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    first = ses.submit(specs[:10])
    assert len(first) == 10
    ses.step_until(specs[9].release + 50.0)
    done_before = ses.observe()["n_completed"]
    late = ses.submit(specs[10:], shift="now")
    assert len(late) == 15
    r = ses.run()
    assert len(r.completions) == 25
    assert r.completions.keys() == {s.jid for s in specs}
    assert done_before <= 10


def test_submit_validation():
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[-1].release + 1.0)
    with pytest.raises(ValueError, match="duplicate job ids"):
        ses.submit(specs[:1], shift="now")
    with pytest.raises(ValueError, match="shift"):
        ses.submit([dataclasses.replace(specs[0], jid=999, release=0.0)])
    # batch validation applies per submit batch
    big = dataclasses.replace(specs[0], jid=998, n_tasks=64)
    bses = open_session(16, "EASY")
    with pytest.raises(ValueError, match="needs 64"):
        bses.submit([big])


def test_submit_after_exhaustion_rearms_the_session():
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs[:5])
    ses.run_to_exhaustion()
    assert ses.exhausted
    partial = ses.result()
    assert len(partial.completions) == 5
    ses.submit(specs[5:10], shift="now")
    assert not ses.exhausted
    r = ses.run()
    assert len(r.completions) == 10


def test_inject_validation_and_effect():
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[0].release + 200.0)
    with pytest.raises(ValueError, match="outside"):
        ses.inject({"kind": "fail", "t": ses.now + 1, "nodes": [99]})
    with pytest.raises(ValueError, match="past|clock"):
        ses.inject({"kind": "fail", "t": ses.engine.state.now - 50.0,
                    "nodes": [0]})
    # live failure conditioned on observed state
    obs = ses.observe()
    assert obs["alive_nodes"] == 16
    ses.inject({"kind": "fail", "t": ses.now + 10.0,
                "nodes": list(range(8))})
    ses.step_until(ses.now + 11.0)
    assert ses.observe()["alive_nodes"] == 8
    ses.inject({"kind": "join", "t": ses.now + 100.0,
                "nodes": list(range(8))})
    r = ses.run()
    assert len(r.completions) == 25

    # batch baselines do not model failures
    bses = open_session(16, "EASY")
    bses.submit(specs)
    with pytest.raises(ValueError, match="cluster events"):
        bses.inject({"kind": "fail", "t": 1e9, "nodes": [0]})


def test_period_change_takes_effect_live():
    specs = make_trace(W_SMALL)
    ref = Engine(specs, "Greedy */per/OPT=MIN",
                 SimParams(n_nodes=16)).run()
    ses = open_session(16, "Greedy */per/OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[0].release + 0.3 * ref.makespan)
    ses.inject({"kind": "period", "period": 60.0})
    r = ses.run()
    assert r.events > ref.events       # much denser tick train afterwards


def test_partial_result_and_observe():
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step(8)
    obs = ses.observe()
    r = ses.result()                   # partial: events remain
    assert len(r.completions) == obs["n_completed"] < 25
    assert r.final_time == ses.engine.state.now
    assert r.n_events == r.events == obs["events"]
    assert r.sim_wall_s > 0.0
    full = ses.run()
    assert len(full.completions) == 25
    assert not math.isinf(full.final_time)


# --------------------------------------------------------------------------- #
# what-if branching                                                            #
# --------------------------------------------------------------------------- #
def test_fork_same_policy_is_exact_continuation():
    specs, events, params = _cell(W_SMALL, "GreedyP */OPT=MIN",
                                  "rack_failure")
    ses = _session_for(specs, "GreedyP */OPT=MIN", params, events)
    ses.step_until(specs[0].release + 4000.0)
    fork = ses.fork()
    assert _result_dict(fork.run()) == _result_dict(ses.run())


def test_fork_policy_switch_adopts_live_state():
    specs = make_trace(WorkloadSpec("lublin", n_jobs=30, n_nodes=16,
                                    seed=3, load=1.2))
    ses = open_session(16, "GreedyPM */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[0].release + 3000.0)
    for alt in ["Greedy */per/OPT=MIN", "EASY", "FCFS"]:
        branch = ses.fork(policy=alt)
        r = branch.run()
        assert r.completions.keys() == {s.jid for s in specs}, alt
    straight = ses.run()
    assert len(straight.completions) == 30


def test_run_branches_records(tmp_path):
    specs = make_trace(W_SMALL)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[0].release + 3000.0)
    snap = ses.snapshot()
    path = str(tmp_path / "branches.json")
    res = run_branches(snap, ["greedyp */opt=min", "GreedyPM */OPT=MIN",
                              "EASY"], json_path=path)
    assert res.n_cells == 3
    by_policy = {r["policy"]: r for r in res.records}
    # spelling-insensitive exact-continuation detection
    assert by_policy["greedyp */opt=min"]["exact_continuation"]
    assert not by_policy["EASY"]["exact_continuation"]
    straight = ses.run()
    assert (by_policy["greedyp */opt=min"]["mean_stretch"]
            == straight.mean_stretch)
    for rec in res.records:
        assert rec["branch_time"] == snap.time
        assert rec["branch_fingerprint"] == snap.fingerprint
        assert {"n_events", "sim_wall_s", "final_time"} <= rec.keys()
    assert json.load(open(path))["schema"] == "repro.sweep/v1"


def test_sweep_records_surface_observability_fields():
    res = api.run_grid(api.grid([W_SMALL], ["FCFS"]), n_workers=1)
    rec = res.records[0]
    assert rec["n_events"] == rec["events"] > 0
    assert rec["final_time"] > 0.0
    assert 0.0 < rec["sim_wall_s"] <= rec["wall_s"]


# --------------------------------------------------------------------------- #
# reactive scenarios                                                           #
# --------------------------------------------------------------------------- #
def test_reactive_surge_submit_reacts_to_observed_drain():
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(make_trace(W_SMALL))
    r = run_reactive(ses, "surge_submit", seed=1)
    assert len(r.completions) > 25        # bursts happened and completed
    assert ses.scratch["surge_submit"]["bursts"] >= 1


def test_reactive_elastic_reserve_round_trips_capacity():
    ses = open_session(16, "GreedyPM */OPT=MIN")
    ses.submit(make_trace(WorkloadSpec("lublin", n_jobs=30, n_nodes=16,
                                       seed=2, load=1.4)))
    r = run_reactive(ses, "elastic_reserve", seed=0, interval=300.0)
    assert len(r.completions) == 30
    assert ses.observe()["alive_nodes"] in (12, 16)


def test_reactive_accepts_ad_hoc_rules_and_unknown_names_fail():
    calls = []

    def watcher(session, obs, rng):
        calls.append(obs["n_completed"])

    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(make_trace(W_SMALL))
    r = run_reactive(ses, watcher, interval=1000.0)
    assert len(r.completions) == 25 and calls and calls[-1] == 25
    with pytest.raises(KeyError, match="unknown reactive"):
        run_reactive(ses, "nope")
    assert "surge_submit" in api.list_reactive()
    assert "drain" in api.reactive_docs()["surge_submit"]


# --------------------------------------------------------------------------- #
# streaming CLI                                                                #
# --------------------------------------------------------------------------- #
def _write_script(path, lines):
    path.write_text("\n".join(json.dumps(l) if isinstance(l, dict) else l
                              for l in lines) + "\n")


def test_cli_session_streams_metrics_and_snapshots(tmp_path, capsys):
    snap_path = str(tmp_path / "snap.json")
    script = tmp_path / "script.jsonl"
    _write_script(script, [
        "# comment lines are skipped",
        {"op": "submit", "workload": "lublin", "jobs": 25, "seed": 0},
        {"op": "step_until", "t": 3000},
        {"op": "inject", "kind": "fail", "t": 3100, "nodes": [0, 1]},
        {"op": "inject", "kind": "join", "t": 4000, "nodes": [0, 1]},
        {"op": "step", "n": 5},
        {"op": "snapshot", "path": snap_path},
        {"op": "run"},
        {"op": "result"},
    ])
    assert cli_main(["session", "--script", str(script),
                     "--policy", "GreedyP */OPT=MIN", "--nodes", "16"]) == 0
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    kinds = [l["kind"] for l in lines]
    assert kinds == ["submit", "step", "inject", "inject", "step",
                     "snapshot", "step", "result"]
    assert lines[0]["n_future"] == 25
    assert lines[-1]["partial"] is False
    assert len(lines[-1]["completions"]) == 25
    straight_result = lines[-1]

    # restore from the snapshot in a new CLI invocation; the resumed run
    # must finish identically to the straight-through run
    resume = tmp_path / "resume.jsonl"
    _write_script(resume, [{"op": "run"}, {"op": "result"}])
    assert cli_main(["session", "--script", str(resume),
                     "--restore", snap_path]) == 0
    resumed = [json.loads(l)
               for l in capsys.readouterr().out.splitlines()][-1]
    for d in (straight_result, resumed):
        d.pop("sim_wall_s")
    assert resumed == straight_result


def test_cli_session_metrics_file_and_open_op(tmp_path):
    metrics = tmp_path / "metrics.jsonl"
    script = tmp_path / "script.jsonl"
    _write_script(script, [
        {"op": "open", "policy": "FCFS", "nodes": 16},
        {"op": "submit", "workload": "lublin", "jobs": 10, "seed": 1},
        {"op": "run"},
        {"op": "result"},
    ])
    assert cli_main(["session", "--script", str(script),
                     "--metrics", str(metrics)]) == 0
    lines = [json.loads(l) for l in metrics.read_text().splitlines()]
    assert [l["kind"] for l in lines] == ["open", "submit", "step", "result"]
    assert lines[0]["policy"] == "FCFS"


def test_cli_session_errors(tmp_path, capsys):
    script = tmp_path / "script.jsonl"
    _write_script(script, [{"op": "wat"}])
    assert cli_main(["session", "--script", str(script),
                     "--policy", "FCFS", "--nodes", "16"]) == 2
    assert "unknown op" in capsys.readouterr().err
    _write_script(script, [{"op": "step", "n": 1}])
    assert cli_main(["session", "--script", str(script)]) == 2
    assert "no session open" in capsys.readouterr().err


# --------------------------------------------------------------------------- #
# compat shims point at the session API                                        #
# --------------------------------------------------------------------------- #
def test_legacy_shims_point_at_open_session_once_per_process():
    from repro.sched.batch import batch_schedule
    specs = make_trace(W_SMALL)
    _compat.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        batch_schedule(specs, "FCFS", SimParams(n_nodes=16))
        batch_schedule(specs, "EASY", SimParams(n_nodes=16))
    msgs = [str(w.message) for w in rec
            if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 1
    assert "repro.api.simulate" in msgs[0]
    assert "repro.api.open_session" in msgs[0]
