"""Sweep subsystem + scenario library + workload registry tests.

Includes the acceptance grid: a 16-cell (2 workloads × 4 policies × 2
scenarios) sweep through run_grid with n_workers=4, producing a JSON
artifact, with parallel results identical to the serial run.
"""
import json

import numpy as np
import pytest

from repro.sched.engine import SimParams
from repro.sched.scenarios import apply_scenario, list_scenarios
from repro.sched.sweep import Cell, grid, run_grid
from repro.workloads.lublin import lublin_trace
from repro.workloads.registry import WorkloadSpec, make_trace

POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
            "GreedyPM */per/OPT=MIN/MINVT=600"]


def small_workloads():
    return [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
            WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]


# --------------------------------------------------------------------------- #
# workload registry                                                             #
# --------------------------------------------------------------------------- #
def test_workload_spec_roundtrip_and_validation():
    w = WorkloadSpec("lublin", n_jobs=10, n_nodes=8, seed=3, load=0.5)
    assert w.to_dict()["load"] == 0.5
    assert "lublin" in w.name and "@0.5" in w.name
    with pytest.raises(ValueError):
        WorkloadSpec("marsaglia")
    with pytest.raises(ValueError):
        WorkloadSpec("hpc2n", load=0.5)


def test_make_trace_deterministic_and_memoized():
    w = WorkloadSpec("lublin", n_jobs=20, n_nodes=16, seed=7)
    a, b = make_trace(w), make_trace(w)
    assert a == b
    assert a is not b            # callers get fresh lists, not the cache
    assert [s.jid for s in a] == list(range(20))


def test_make_trace_scaled_load():
    from repro.workloads.lublin import offered_load
    w = WorkloadSpec("lublin", n_jobs=60, n_nodes=16, seed=0, load=0.5)
    specs = make_trace(w)
    assert offered_load(specs, 16) == pytest.approx(0.5, rel=1e-6)


def test_hpc2n_drops_jobs_wider_than_cluster():
    w = WorkloadSpec("hpc2n", n_jobs=80, n_nodes=32, seed=0)
    specs = make_trace(w)
    assert specs and all(s.n_tasks <= 32 for s in specs)


# --------------------------------------------------------------------------- #
# scenario library                                                              #
# --------------------------------------------------------------------------- #
def test_builtin_scenarios_present():
    names = list_scenarios()
    for expected in ("baseline", "rack_failure", "rolling_failures",
                     "elastic", "arrival_burst", "mem_pressure"):
        assert expected in names


@pytest.mark.parametrize("name", ["baseline", "rack_failure",
                                  "rolling_failures", "elastic",
                                  "arrival_burst", "mem_pressure"])
def test_scenarios_deterministic_and_wellformed(name):
    base = lublin_trace(n_jobs=30, n_nodes=16, seed=1)
    s1, e1 = apply_scenario(name, base, 16, seed=5)
    s2, e2 = apply_scenario(name, base, 16, seed=5)
    assert s1 == s2 and e1 == e2              # deterministic given the seed
    assert len(s1) == len(base)               # scenarios never drop jobs
    for ev in e1:
        assert ev.kind in ("fail", "join")
        assert all(0 <= n < 16 for n in ev.nodes)
    for s in s1:
        assert 0.0 < s.mem_req <= 1.0


def test_arrival_burst_compresses_midspan():
    base = lublin_trace(n_jobs=200, n_nodes=16, seed=2)
    burst, _ = apply_scenario("arrival_burst", base, 16, seed=0)
    span = lambda xs: max(s.release for s in xs) - min(s.release for s in xs)
    assert span(burst) <= span(base)
    # total work untouched — only releases move
    assert sum(s.total_work for s in burst) == pytest.approx(
        sum(s.total_work for s in base))


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        apply_scenario("meteor_strike", [], 4)


def test_scenario_cells_complete_under_failures():
    """A DFRS policy absorbs every built-in scenario end to end."""
    w = WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=3)
    cells = grid([w], ["GreedyPM */per/OPT=MIN/MINVT=600"], list_scenarios())
    res = run_grid(cells, n_workers=1)
    assert res.n_cells == len(list_scenarios())
    for rec in res.records:
        assert rec["makespan"] > 0
        assert not rec["hit_max_events"]


# --------------------------------------------------------------------------- #
# the acceptance grid: 16 cells, 4 workers, JSON artifact                       #
# --------------------------------------------------------------------------- #
def test_16_cell_sweep_parallel_matches_serial(tmp_path):
    cells = grid(small_workloads(), POLICIES, ["baseline", "rack_failure"])
    assert len(cells) == 16
    path = str(tmp_path / "sweep.json")
    par = run_grid(cells, n_workers=4, compute_bound=True, json_path=path)
    ser = run_grid(cells, n_workers=1, compute_bound=True)
    assert par.n_cells == ser.n_cells == 16
    for a, b in zip(ser.records, par.records):
        for k in a:
            if k in ("wall_s", "sim_wall_s"):
                continue        # timing differs; results must not
            assert a[k] == b[k], (k, a[k], b[k])
    # artifact shape
    art = json.loads(open(path).read())
    assert art["schema"] == "repro.sweep/v1"
    assert art["n_cells"] == 16 and len(art["records"]) == 16
    assert art["cells_per_sec"] > 0
    for rec in art["records"]:
        for key in ("workload", "policy", "scenario", "scenario_applied",
                    "max_stretch", "mean_stretch", "makespan", "bound",
                    "degradation"):
            assert key in rec
        assert rec["degradation"] >= 0.99   # never beats the lower bound
        # batch baselines drop ClusterEvents: flagged, not silently claimed
        is_batch = rec["policy"] in ("FCFS", "EASY")
        expect = not (is_batch and rec["scenario"] == "rack_failure")
        assert rec["scenario_applied"] == expect


def test_sweep_result_helpers():
    cells = grid(small_workloads()[:1], POLICIES[:2])
    res = run_grid(cells, n_workers=1)
    assert res.values("max_stretch", policy="FCFS").shape == (1,)
    summ = res.summary(by="policy")
    assert set(summ) == {"FCFS", "EASY"}
    assert all("mean_max_stretch" in v for v in summ.values())


def test_cell_params_template_propagates():
    """A params template reaches the engine (period halved here), while
    n_nodes always comes from the workload spec."""
    w = WorkloadSpec("lublin", n_jobs=25, n_nodes=16, seed=0)
    fast = run_grid([Cell(w, "/per/OPT=MIN",
                          params=SimParams(period=300.0))], n_workers=1)
    slow = run_grid([Cell(w, "/per/OPT=MIN",
                          params=SimParams(period=6000.0))], n_workers=1)
    # more frequent MCB8 passes do strictly more events
    assert fast.records[0]["events"] > slow.records[0]["events"]


# --------------------------------------------------------------------------- #
# Trace-IR sweep path: worker-count identity, memoization, fingerprints         #
# --------------------------------------------------------------------------- #
def test_run_grid_worker_counts_produce_identical_record_sets():
    """workers=1 and workers=4 must yield the same records (ordering-
    independent) on a grid spanning registry kinds (swf included) and a
    composed scenario chain."""
    import os
    mini_swf = os.path.join(os.path.dirname(__file__), "data", "mini.swf")
    from repro.workloads.registry import parse_workload
    workloads = [WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=0),
                 parse_workload(f"swf:{mini_swf}", n_jobs=0, n_nodes=128),
                 WorkloadSpec("tpu", n_jobs=25, n_nodes=64, seed=1)]
    cells = grid(workloads, ["FCFS", "GreedyP */OPT=MIN"],
                 ["baseline", "rack_failure+arrival_burst"])
    ser = run_grid(cells, n_workers=1, compute_bound=True)
    par = run_grid(cells, n_workers=4, compute_bound=True)
    assert ser.n_cells == par.n_cells == 12

    def strip(recs):
        return sorted((tuple(sorted((k, str(v)) for k, v in r.items()
                                    if k not in ("wall_s", "sim_wall_s")))
                       for r in recs))
    assert strip(ser.records) == strip(par.records)
    for rec in ser.records:
        assert rec["trace_fingerprint"]
        assert "params" in rec


def test_make_trace_memoization_hits_under_registry():
    """Per-process trace materialization memoizes by WorkloadSpec: repeated
    cells of a policy sweep share one frozen Trace object."""
    from repro.workloads.registry import (make_trace_ir, make_trace,
                                          trace_cache_info)
    w = WorkloadSpec("lublin", n_jobs=12, n_nodes=8, seed=987654)
    t1 = make_trace_ir(w)
    before = trace_cache_info().hits
    t2 = make_trace_ir(w)
    assert t2 is t1                    # the same frozen object, not a copy
    assert trace_cache_info().hits == before + 1
    # the spec-list view is a fresh list per call (callers may mutate it)
    a, b = make_trace(w), make_trace(w)
    assert a == b and a is not b


def test_scenario_chain_through_run_grid():
    w = WorkloadSpec("lublin", n_jobs=25, n_nodes=16, seed=2)
    res = run_grid(grid([w], ["GreedyPM */per/OPT=MIN/MINVT=600"],
                        ["rack_failure+mem_pressure"]), n_workers=1)
    rec = res.records[0]
    assert rec["scenario"] == "rack_failure+mem_pressure"
    assert rec["scenario_applied"] and rec["makespan"] > 0


def test_record_cache_fingerprint_guards_generator_refactors(tmp_path,
                                                             monkeypatch):
    """A cached record is reused only while the workload trace's content
    fingerprint matches: refactoring a generator (same spec, different
    jobs) must re-simulate, not serve stale records."""
    import dataclasses as dc
    from repro.sched.sweep import RecordCache
    import repro.sched.sweep as sweep_mod
    from repro.workloads import registry as reg

    path = str(tmp_path / "cache.json")
    w = WorkloadSpec("lublin", n_jobs=15, n_nodes=16, seed=0)
    RecordCache(path).sweep([w], ["FCFS"], n_workers=1, compute_bound=False)

    # warm resume with the unchanged generator: no simulation
    monkeypatch.setattr(sweep_mod, "run_grid",
                        lambda *a, **kw: pytest.fail("warm cache missed"))
    warm = RecordCache(path).sweep([w], ["FCFS"], n_workers=1,
                                   compute_bound=False)
    assert len(warm) == 1
    monkeypatch.undo()

    # "refactor" the lublin generator: same spec now yields different jobs
    orig_kind = reg._REGISTRY["lublin"]
    patched = dc.replace(
        orig_kind,
        fn=lambda spec: orig_kind.fn(spec).select(np.arange(spec.n_jobs - 1)))
    monkeypatch.setitem(reg._REGISTRY, "lublin", patched)
    reg.trace_cache_clear()
    try:
        calls = []
        orig_run = sweep_mod.run_grid
        monkeypatch.setattr(
            sweep_mod, "run_grid",
            lambda cells, **kw: calls.append(len(cells)) or orig_run(cells, **kw))
        recs = RecordCache(path).sweep([w], ["FCFS"], n_workers=1,
                                       compute_bound=False)
        assert calls == [1]            # fingerprint moved -> re-simulated
        assert len(recs) == 1
    finally:
        reg.trace_cache_clear()


def test_record_cache_skips_pre_fingerprint_records(tmp_path):
    """Records written before the Trace-IR refactor (no trace_fingerprint /
    params fields) load as misses instead of poisoning the key space."""
    from repro.sched.sweep import RecordCache
    path = str(tmp_path / "cache.json")
    w = WorkloadSpec("lublin", n_jobs=12, n_nodes=16, seed=1)
    RecordCache(path).sweep([w], ["FCFS"], n_workers=1, compute_bound=False)
    payload = json.loads(open(path).read())
    for rec in payload["records"]:
        rec.pop("trace_fingerprint")
    open(path, "w").write(json.dumps(payload))
    assert len(RecordCache(path)) == 0
