"""Per-arch smoke tests (reduced configs) + model-level invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, get_reduced, shape_applicable
from repro.models import backbone, moe
from repro.models.config import layer_groups, layer_plan


def _batch(cfg, B=2, S=16, seed=1):
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S), 1, cfg.vocab)}
    if cfg.is_encdec:
        b["enc_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model))
    if cfg.frontend == "vision":
        b["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (B, 4, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_prefill_decode(arch):
    """One forward/train step + prefill + decode on CPU: shapes, no NaNs."""
    cfg = get_reduced(arch)
    params, axes = backbone.init_params(cfg, jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, axes,
                                        is_leaf=lambda x: isinstance(x, tuple)))
    B, S = 2, 16
    b = _batch(cfg, B, S)
    loss, metrics = backbone.lm_loss(cfg, params, b)
    assert loss.shape == () and not bool(jnp.isnan(loss))

    caches = backbone.init_cache(cfg, B, 32, S_enc=8 if cfg.is_encdec else 0)
    logits, caches = backbone.prefill(cfg, params, b, caches)
    assert logits.shape == (B, cfg.vocab)
    lg, caches = backbone.decode_step(
        cfg, params, jnp.ones((B,), jnp.int32), caches, jnp.int32(S))
    assert lg.shape == (B, cfg.vocab) and not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ["llama3_8b", "deepseek_v3_671b", "rwkv6_7b",
                                  "recurrentgemma_2b", "whisper_large_v3"])
def test_decode_matches_dense_forward(arch):
    """prefill+decode logits == full-forward logits at the same position.

    MoE archs need an ample capacity factor: token drops depend on how many
    tokens compete for an expert, which legitimately differs between a
    13-token train forward and a 1-token decode step."""
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    b = _batch(cfg, B, S + 1, seed=7)
    # dense forward over S+1 tokens -> logits at position S-1 predicts token S
    h = backbone.embed_tokens(cfg, params, b["tokens"])
    enc_out = backbone.encode(cfg, params, b["enc_embeds"]) if cfg.is_encdec else None
    hf, _, _ = backbone.forward(cfg, params, h, "train", enc_out=enc_out)
    dense_logits = backbone.logits_fn(cfg, params, hf[:, S - 1])

    caches = backbone.init_cache(cfg, B, 32, S_enc=8 if cfg.is_encdec else 0,
                                 dtype=jnp.float32)
    pre = {k: (v[:, :S] if k == "tokens" else v) for k, v in b.items()}
    lg_prefill, caches = backbone.prefill(cfg, params, pre, caches)
    np.testing.assert_allclose(np.asarray(lg_prefill[0]),
                               np.asarray(dense_logits[0]),
                               atol=2e-3, rtol=2e-3)
    # decode one token: must match dense logits at position S
    lg_dec, _ = backbone.decode_step(
        cfg, params, b["tokens"][:, S], caches, jnp.int32(S))
    dense_S = backbone.logits_fn(cfg, params, hf[:, S])
    np.testing.assert_allclose(np.asarray(lg_dec[0]), np.asarray(dense_S[0]),
                               atol=2e-3, rtol=2e-3)


def test_layer_plans_match_specs():
    """Layer counts/patterns follow the assigned-architecture table."""
    ds = get_config("deepseek-v3-671b")
    plan = layer_plan(ds)
    assert len(plan) == 61
    assert all(b.kind == "mla" for b in plan)
    assert [b.mlp for b in plan[:3]] == ["dense"] * 3
    assert all(b.mlp == "moe" for b in plan[3:])

    rg = get_config("recurrentgemma-2b")
    plan = layer_plan(rg)
    assert len(plan) == 26
    kinds = [b.kind for b in plan[:6]]
    assert kinds == ["rglru", "rglru", "local", "rglru", "rglru", "local"]

    rw = get_config("rwkv6-7b")
    assert all(b.kind == "rwkv6" for b in layer_plan(rw))

    wh = get_config("whisper-large-v3")
    assert wh.encoder_layers == 32 and wh.n_layers == 32
    assert all(b.cross_attn for b in layer_plan(wh))


def test_param_counts_match_published():
    """Analytic parameter counts within 10% of the published sizes."""
    expect = {
        "llama3-8b": 8.0e9, "qwen3-8b": 8.2e9, "granite-3-2b": 2.5e9,
        "smollm-360m": 3.6e8, "deepseek-v3-671b": 6.7e11,
        "qwen2-moe-a2.7b": 1.4e10, "rwkv6-7b": 7.6e9,
        "internvl2-76b": 7.0e10, "recurrentgemma-2b": 2.7e9,
        "whisper-large-v3": 1.5e9,
    }
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert abs(got - n) / n < 0.25, (name, got, n)


def test_moe_grouping_invariance_and_aux():
    cfg = dataclasses.replace(get_reduced("qwen2-moe-a2.7b"), capacity_factor=8.0)
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg, 2, 16)
    l1, m1 = backbone.lm_loss(cfg, params, b)
    try:
        moe.set_groups(4)
        l4, m4 = backbone.lm_loss(cfg, params, b)
    finally:
        moe.set_groups(1)
    assert float(l1) == pytest.approx(float(l4), rel=1e-5)
    assert float(m1["aux"]) > 0.0      # load-balance loss active


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 the routed output degrades but stays finite."""
    cfg = dataclasses.replace(get_reduced("qwen2-moe-a2.7b"), capacity_factor=0.1)
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = backbone.lm_loss(cfg, params, _batch(cfg, 2, 16))
    assert not bool(jnp.isnan(loss))


def test_expert_padding_is_inert():
    """Padded (dead) experts change shapes, not routing results: with ample
    capacity the loss is finite and padded experts receive zero probability."""
    cfg = dataclasses.replace(get_reduced("qwen2-moe-a2.7b"),
                              capacity_factor=8.0, n_experts_pad=4)
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    E_alloc = cfg.n_experts + cfg.n_experts_pad
    assert params["groups"][0]["mlp"]["wg"].shape[1] == E_alloc
    loss, _ = backbone.lm_loss(cfg, params, _batch(cfg, 2, 16))
    assert bool(jnp.isfinite(loss))


def test_int8_kv_cache_decode_close_to_fp():
    cfg = get_reduced("llama3-8b")
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    b = _batch(cfg, B, S, seed=4)
    outs = {}
    for name, dt in (("fp", jnp.float32), ("int8", jnp.int8)):
        caches = backbone.init_cache(cfg, B, 32, dtype=dt)
        if name == "int8":
            kv = [l for l in jax.tree.leaves(caches) if l.dtype == jnp.int8]
            assert kv, "int8 layout must be used"
        _, caches = backbone.prefill(cfg, params, b, caches)
        lg, _ = backbone.decode_step(cfg, params, jnp.ones((B,), jnp.int32),
                                     caches, jnp.int32(S))
        outs[name] = lg
    err = float(jnp.abs(outs["fp"] - outs["int8"]).max())
    scale = float(jnp.abs(outs["fp"]).max())
    assert err < 0.05 * max(scale, 1.0)


def test_long_500k_applicability_flags():
    runs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCHS}
    assert runs["rwkv6_7b"] and runs["recurrentgemma_2b"]
    assert sum(runs.values()) == 2     # all full-attention archs skip


def test_sliding_window_cache_is_bounded():
    cfg = get_reduced("recurrentgemma-2b")
    caches = backbone.init_cache(cfg, 1, 10_000)
    # local-attention KV (the only 5-D leaves: (L,B,S,H,hd)) must be
    # window-sized, not context-sized
    kv = [l for l in jax.tree.leaves(caches) if l.ndim == 5]
    assert kv and max(l.shape[2] for l in kv) <= cfg.window
