"""Hypothesis property tests: vectorized allocation kernels vs the
pre-vectorization reference oracle (bitwise equality on arbitrary inputs).

Complements tests/test_alloc_kernels.py (seeded, runs on minimal installs):
hypothesis explores the input space adversarially — degenerate single-node
clusters, yield-capped jobs, saturated memory — and shrinks any mismatch to
a minimal counterexample.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import alloc_reference as ref
from repro.core.greedy import greedy_place
from repro.core.job import JobSpec, NodePool
from repro.core.mcb8 import mcb8_pack
from repro.core.alloc_kernels import reference_kernels
from repro.core.yield_alloc import avg_yields, maxmin_yields

job_st = st.builds(
    JobSpec,
    jid=st.integers(0, 10_000),
    release=st.floats(0, 1e5),
    proc_time=st.floats(1.0, 1e5),
    n_tasks=st.integers(1, 16),
    cpu_need=st.sampled_from([0.25, 0.5, 1.0]),
    mem_req=st.sampled_from([0.1, 0.2, 0.3, 0.5, 0.8, 1.0]),
)


def _place_all(specs, n_nodes):
    pool = NodePool(n_nodes)
    placed, maps = [], []
    for i, s in enumerate(specs):
        spec = JobSpec(jid=i, release=0.0, proc_time=s.proc_time,
                       n_tasks=s.n_tasks, cpu_need=s.cpu_need,
                       mem_req=s.mem_req)
        m = ref.greedy_place(pool, spec)
        if m is not None:
            placed.append(spec)
            maps.append(m)
    return placed, maps


@settings(max_examples=60, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=14), st.integers(1, 10))
def test_maxmin_yields_matches_reference(specs, n_nodes):
    placed, maps = _place_all(specs, n_nodes)
    if not placed:
        return
    assert np.array_equal(maxmin_yields(placed, maps, n_nodes),
                          ref.maxmin_yields(placed, maps, n_nodes))


@settings(max_examples=25, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=10), st.integers(1, 8))
def test_avg_yields_matches_reference(specs, n_nodes):
    placed, maps = _place_all(specs, n_nodes)
    if not placed:
        return
    assert np.array_equal(avg_yields(placed, maps, n_nodes),
                          ref.avg_yields(placed, maps, n_nodes))


@settings(max_examples=60, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=14), st.integers(1, 10))
def test_greedy_place_matches_reference(specs, n_nodes):
    pa, pb = NodePool(n_nodes), NodePool(n_nodes)
    for s in specs:
        assert greedy_place(pa, s) == ref.greedy_place(pb, s)
        assert np.array_equal(pa.load, pb.load)
        assert np.array_equal(pa.mem_free, pb.mem_free)


@settings(max_examples=40, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=18), st.integers(2, 16),
       st.floats(0.01, 1.0))
def test_mcb8_pack_matches_reference(specs, n_nodes, y):
    jobs = [(i, min(1.0, s.cpu_need * y), s.mem_req, s.n_tasks)
            for i, s in enumerate(specs)]
    fast = mcb8_pack(n_nodes, jobs)
    with reference_kernels():
        slow = mcb8_pack(n_nodes, jobs)
    assert fast == slow
