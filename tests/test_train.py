"""Training substrate tests: optimizer, data, checkpoint, FT, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.train import checkpoint as ckpt
from repro.train.compression import compress_int8, decompress_int8
from repro.train.data import data_for
from repro.train.ft import FailureInjector, StragglerStats, run_restartable
from repro.train.optimizer import (OptConfig, adafactor_update, adamw_update,
                                   init_opt_state, lr_schedule, opt_axes)
from repro.train.trainer import init_train_state, make_train_step

CFG = get_reduced("smollm-360m")


# --------------------------------------------------------------------------- #
# optimizer                                                                    #
# --------------------------------------------------------------------------- #
def _toy_params():
    return {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}


def test_adamw_descends_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0, total_steps=100)
    params = _toy_params()
    state = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 50


def test_adafactor_descends_and_state_is_factored():
    cfg = OptConfig(lr=0.1, warmup_steps=0, weight_decay=0.0, factored=True,
                    total_steps=100)
    params = _toy_params()
    state = init_opt_state(params, factored=True)
    assert set(state.nu["w"]) == {"vr", "vc"}
    assert state.nu["w"]["vr"].shape == (8,)
    assert state.nu["w"]["vc"].shape == (4,)
    assert state.nu["b"].shape == (4,)           # 1-D stays unfactored
    assert state.mu["w"].dtype == jnp.bfloat16
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adafactor_update(cfg, params, g, state)
    assert float(loss(params)) < 0.05


def test_opt_axes_mirror_structure():
    params = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    axes = {"w": ("d_model", "d_ff"), "b": ("d_ff",)}
    oa = opt_axes(axes, params, factored=True)
    assert oa.nu["w"] == {"vr": ("d_model",), "vc": ("d_ff",)}
    assert oa.nu["b"] == ("d_ff",)
    assert oa.mu == axes


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 200)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0, abs=0.1)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1)


def test_gradient_clipping_bounds_update():
    cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(cfg, params, g, init_opt_state(params))
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


# --------------------------------------------------------------------------- #
# gradient compression                                                         #
# --------------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_int8_error_feedback_invariant(seed):
    """decompress(compress(g)) + err == g (exact residual bookkeeping)."""
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.normal(size=(16, 8)) * rng.uniform(0.1, 100)),
         "b": jnp.asarray(rng.normal(size=(5,)))}
    comp, err = compress_int8(g)
    deq = decompress_int8(comp)
    for k in g:
        np.testing.assert_allclose(np.asarray(deq[k] + err[k]),
                                   np.asarray(g[k], np.float32),
                                   rtol=1e-6, atol=1e-6)
        assert comp.q[k].dtype == jnp.int8
        # quantization error bounded by half a step
        step = float(comp.scale[k])
        assert np.abs(np.asarray(err[k])).max() <= step * 0.5 + 1e-6


def test_compressed_training_still_learns():
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(make_train_step(CFG, opt, compress_grads=True))
    state = init_train_state(CFG, jax.random.PRNGKey(0), compress=True)
    data = data_for(CFG, 4, 32)
    losses = []
    for i in range(8):
        state, m = step(state, data.batch_for_step(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert state.err is not None


# --------------------------------------------------------------------------- #
# data pipeline                                                                #
# --------------------------------------------------------------------------- #
def test_data_deterministic_and_restart_safe():
    d1 = data_for(CFG, 4, 64, seed=3)
    d2 = data_for(CFG, 4, 64, seed=3)
    b1, b2 = d1.batch_for_step(17), d2.batch_for_step(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch_for_step(18)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert (np.asarray(b1["tokens"]) < CFG.vocab).all()
    # document boundaries
    assert (np.asarray(b1["tokens"])[:, 0] == 0).all()


def test_data_frontend_extras():
    wcfg = get_reduced("whisper-large-v3")
    d = data_for(wcfg, 2, 32, n_enc=16)
    b = d.batch_for_step(0)
    assert b["enc_embeds"].shape == (2, 16, wcfg.d_model)
    vcfg = get_reduced("internvl2-76b")
    d = data_for(vcfg, 2, 32)
    assert "vision_embeds" in d.batch_for_step(0)


# --------------------------------------------------------------------------- #
# checkpointing                                                                #
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_latest():
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.latest_step(d) is None
        ckpt.save(d, 5, state, metadata={"loss": 1.0})
        ckpt.save(d, 10, state)
        assert ckpt.latest_step(d) == 10
        step, restored, meta = ckpt.restore(d, template=state, step=5)
        assert step == 5 and meta["loss"] == 1.0
        ok = jax.tree.all(jax.tree.map(
            lambda a, b: jnp.allclose(a, b), state, restored))
        assert bool(ok)


def test_checkpoint_rejects_shape_mismatch():
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        bad = jax.tree.map(lambda x: jnp.zeros((3, 3)), state)
        with pytest.raises(ValueError):
            ckpt.restore(d, template=bad)


def test_checkpoint_async_commit():
    state = {"x": jnp.arange(10)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_async(d, 7, state)
        ckpt.wait_pending()
        step, restored, _ = ckpt.restore(d, template=state)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(10))


# --------------------------------------------------------------------------- #
# fault tolerance                                                              #
# --------------------------------------------------------------------------- #
def test_restartable_run_is_bitwise_deterministic():
    """A run with injected failures converges to the same final loss as an
    uninterrupted run (deterministic data + checkpoint resume)."""
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(make_train_step(CFG, opt))
    data = data_for(CFG, 4, 32)
    mk = lambda: init_train_state(CFG, jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as d:
        clean = run_restartable(step, mk, data.batch_for_step, 12, d,
                                ckpt_every=4)
    with tempfile.TemporaryDirectory() as d:
        faulty = run_restartable(step, mk, data.batch_for_step, 12, d,
                                 ckpt_every=4,
                                 injector=FailureInjector(at_steps=(6, 9)))
    assert faulty.n_restarts == 2
    assert faulty.restored_from            # actually resumed from disk
    assert faulty.losses[-1] == pytest.approx(clean.losses[-1], rel=1e-5)


def test_straggler_detection():
    s = StragglerStats()
    for _ in range(10):
        s.observe(0.1, factor=3.0)
    assert not s.observe(0.15, factor=3.0)
    assert s.observe(1.0, factor=3.0)       # 10x the EMA
    assert s.n_stragglers == 1
    assert s.worst_ratio > 3.0
