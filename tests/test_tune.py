"""The online what-if autotuner: fork-race-promote over live sessions.

* spec/objective grammars (``parse_tune``, ``parse_objective``) and the
  scoring contract (missing/non-finite metrics lose);
* the session hot-swap surface: ``switch_policy`` equivalence to a
  fork-and-switch, its refusals, and the ``set_period`` aliasing fix;
* ``run_branches`` horizon/early-stop/branch-seed extensions and
  quarantined crashing branches;
* successive-halving races: champion/challenger selection, incumbent tie
  preference, a crashing variant losing (not killing) the race;
* determinism: the decision log is invariant to step partitioning, to
  snapshot/restore (same and fresh process), and an incumbent-pinned
  tuner reproduces the untuned ``SimResult`` bit for bit;
* end-to-end wiring: ``api.autotune``, the session CLI ``--autotune`` /
  ``tune`` op, the ``tune`` subcommand, and the serve-layer ``tune`` op.
"""
import json
import math
import os
import subprocess
import sys

import pytest

from conftest import result_dict
from repro import api
from repro.__main__ import main as cli_main
from repro.sched.sweep import run_branches
from repro.tune import (AutoTuner, TuneConfig, Variant, parse_objective,
                        parse_tune, race)

GREEDY_P = "GreedyP */OPT=MIN"
GREEDY_PM = "GreedyPM */per/OPT=MIN/MINVT=600"
NODES = 32
RACK = list(range(8))


def _rack_failure_session(policy=GREEDY_P, jobs=80, seed=7, load=1.1,
                          fail_t=2050.0, join_t=6000.0, narrator=None,
                          narrator_seed=9):
    """The chaos cell every e2e test runs: a rack failure with a late
    rejoin, where the migration policy digs out better than GreedyP."""
    ses = api.open_session(NODES, policy)
    if narrator:
        ses.attach_narrator(api.parse_narrator(narrator, seed=narrator_seed))
    ses.submit(api.parse_workload("lublin", n_jobs=jobs, n_nodes=NODES,
                                  seed=seed, load=load))
    ses.inject({"kind": "fail", "t": fail_t, "nodes": RACK})
    ses.inject({"kind": "join", "t": join_t, "nodes": RACK})
    return ses


SPEC = ("every=1500;horizon=4000;rungs=2;margin=0.01;dwell=0;"
        f"policies={GREEDY_P}|{GREEDY_PM}")


# --------------------------------------------------------------------------- #
# grammars                                                                     #
# --------------------------------------------------------------------------- #
def test_parse_tune_grammar():
    cfg = parse_tune("every=5000;horizon=2500;rungs=3;margin=0.1;"
                     f"dwell=9000;objective=mean_stretch;"
                     f"policies={GREEDY_P}|{GREEDY_PM};periods=600,1200")
    assert cfg.every == 5000.0 and cfg.horizon == 2500.0
    assert cfg.rungs == 3 and cfg.margin == 0.1 and cfg.dwell == 9000.0
    assert cfg.policies == (GREEDY_P, GREEDY_PM)
    assert cfg.periods == (600.0, 1200.0)
    # derived defaults
    d = parse_tune("every=1000")
    assert d.base_horizon == 500.0 and d.min_dwell == 2000.0


@pytest.mark.parametrize("bad", [
    "every=0", "rungs=0;every=10", "margin=1.5;every=10",
    "nonsense=1", "every", "objective=not_a_metric",
])
def test_parse_tune_rejects(bad):
    with pytest.raises(ValueError):
        parse_tune(bad)


def test_parse_objective_names_blends_and_errors():
    assert parse_objective("max_stretch").prunable_by_max_stretch
    blend = parse_objective("0.7*max_stretch+0.3*mean_stretch")
    assert blend.terms == ((0.7, "max_stretch"), (0.3, "mean_stretch"))
    assert not blend.prunable_by_max_stretch
    assert blend.score({"max_stretch": 10.0, "mean_stretch": 2.0}) \
        == pytest.approx(7.6)
    # quarantined / metric-less records lose
    assert blend.score({"max_stretch": 10.0}) == math.inf
    assert blend.score({"max_stretch": 10.0, "mean_stretch": float("nan")}) \
        == math.inf
    with pytest.raises(ValueError, match="unknown objective metric"):
        parse_objective("wall_s")
    with pytest.raises(ValueError, match="malformed"):
        parse_objective("2**max_stretch")


# --------------------------------------------------------------------------- #
# the hot-swap surface                                                         #
# --------------------------------------------------------------------------- #
def test_set_period_does_not_mutate_shared_params():
    from repro.sched.engine import Engine, SimParams

    params = SimParams(n_nodes=16, period=600.0)
    specs = api.make_trace(api.parse_workload("lublin", n_jobs=10,
                                              n_nodes=16, seed=0))
    ses = api.SimSession.from_engine(Engine(specs, "FCFS", params))
    ses.set_period(150.0)
    assert ses.engine.params.period == 150.0
    assert params.period == 600.0          # the caller's template survives


def test_set_period_survives_snapshot_roundtrip():
    ses = _rack_failure_session(GREEDY_PM)
    ses.step_until(1000.0)
    ses.set_period(333.0)
    restored = api.SimSession.restore(ses.snapshot())
    assert restored.engine.params.period == 333.0
    ses.run_to_exhaustion()
    restored.run_to_exhaustion()
    assert result_dict(restored.result()) == result_dict(ses.result())


def test_switch_policy_equals_fork_switch():
    ses = _rack_failure_session()
    ses.step_until(2500.0)
    forked = api.SimSession.restore(ses.snapshot(), policy=GREEDY_PM)
    ses.switch_policy(GREEDY_PM)
    assert ses.policy_name == GREEDY_PM
    ses.run_to_exhaustion()
    forked.run_to_exhaustion()
    assert result_dict(ses.result()) == result_dict(forked.result())


def test_switch_policy_refusals():
    # pending future cluster events: a batch policy cannot absorb them
    ses = _rack_failure_session()
    ses.step_until(100.0)
    with pytest.raises(ValueError):
        ses.switch_policy("EASY")
    # dead nodes: same refusal once the failure has struck
    ses.step_until(6500.0)
    ses2 = _rack_failure_session(join_t=40000.0)
    ses2.step_until(3000.0)
    with pytest.raises(ValueError):
        ses2.switch_policy("EASY")
    # a DFRS policy that handles cluster events swaps in fine either way
    ses2.switch_policy(GREEDY_PM)
    assert ses2.policy_name == GREEDY_PM


# --------------------------------------------------------------------------- #
# run_branches: horizons, early stop, quarantine                               #
# --------------------------------------------------------------------------- #
def test_run_branches_horizon_and_seed_fields():
    ses = _rack_failure_session()
    ses.step_until(2500.0)
    snap = ses.snapshot()
    res = run_branches(snap, [GREEDY_P, {"policy": GREEDY_PM,
                                         "period": 300.0}],
                       horizon_s=1000.0, branch_seed=42)
    assert len(res.records) == 2
    for rec in res.records:
        assert rec["horizon_s"] == 1000.0
        assert rec["branch_seed"] == 42
        assert rec["partial"] is True
        assert rec["final_time"] <= snap.time + 1000.0 + 1e-9
    # a reseeded branch is no longer the exact live continuation, and a
    # period override marks the record
    assert not res.records[0]["exact_continuation"]
    assert res.records[1]["period"] == 300.0


def test_run_branches_unbounded_same_policy_is_exact_continuation():
    ses = api.open_session(NODES, GREEDY_P)
    ses.submit(api.parse_workload("lublin", n_jobs=40, n_nodes=NODES,
                                  seed=3, load=1.0))
    ses.step_until(1500.0)
    snap = ses.snapshot()
    res = run_branches(snap, [GREEDY_P])
    rec = res.records[0]
    assert rec["exact_continuation"] and not rec["partial"]
    ses.run_to_exhaustion()
    assert rec["max_stretch"] == ses.result(light=True).max_stretch


def test_run_branches_early_stop_and_quarantine():
    ses = _rack_failure_session()
    ses.step_until(2500.0)
    snap = ses.snapshot()
    res = run_branches(snap, [GREEDY_P, "NotAPolicy/NOPE"],
                       horizon_s=3000.0,
                       early_stop={"max_stretch_above": 0.5},
                       quarantine=True)
    ok, bad = res.records
    # every completed job has stretch >= 1, so the first look point trips
    assert ok["early_stopped"] and ok["partial"]
    assert bad["quarantined"] and "NotAPolicy" in bad["policy"]
    assert "error" in bad and bad["horizon_s"] == 3000.0
    # without quarantine the crash propagates
    with pytest.raises(ValueError):
        run_branches(snap, ["NotAPolicy/NOPE"])


# --------------------------------------------------------------------------- #
# races                                                                        #
# --------------------------------------------------------------------------- #
def test_race_crashing_variant_loses_and_winner_promotes():
    ses = _rack_failure_session(join_t=7000.0, jobs=150)
    ses.step_until(6000.0)
    rr = race(ses.snapshot(),
              [Variant("NotAPolicy/NOPE"), Variant(GREEDY_PM)],
              Variant(GREEDY_P, 600.0),
              base_horizon=2000.0, rungs=2, branch_seed=1)
    assert rr.winner.policy == GREEDY_PM and rr.promoted
    assert rr.winner_score < rr.incumbent_score
    # the crasher scored inf on rung 0 and was eliminated there
    r0 = rr.rungs[0]
    bad = r0["variants"].index("NotAPolicy/NOPE")
    assert r0["scores"][bad] == math.inf
    assert "NotAPolicy/NOPE" in r0["eliminated"]
    assert len(rr.rungs) == 2


def test_race_empty_portfolio_and_tie_prefers_incumbent():
    ses = _rack_failure_session()
    ses.step_until(1000.0)
    snap = ses.snapshot()
    rr = race(snap, [], Variant(GREEDY_P, 600.0),
              base_horizon=500.0, rungs=1)
    assert not rr.promoted and rr.winner.key() == Variant(
        GREEDY_P, 600.0).key()
    # an identically-scoring duplicate (same policy, explicit period)
    # never displaces the incumbent
    rr = race(snap, [Variant(GREEDY_P)], Variant(GREEDY_P, 600.0),
              base_horizon=500.0, rungs=1)
    assert rr.winner.key() == Variant(GREEDY_P, 600.0).key()
    assert rr.winner_score == rr.incumbent_score


# --------------------------------------------------------------------------- #
# determinism                                                                  #
# --------------------------------------------------------------------------- #
CHAOS = "breakdown(mtbf=8000,repair=1500)"
CHAOS_SPEC = ("every=3000;rungs=2;margin=0.02;dwell=6000;"
              f"policies={GREEDY_P}|{GREEDY_PM}")


def _chaos_tuned(step=None, snapshot_at=None):
    """One chaos-narrated, autotuned run; optionally step-partitioned
    and/or round-tripped through a snapshot mid-run."""
    ses = api.open_session(NODES, GREEDY_P)
    ses.attach_narrator(api.parse_narrator(CHAOS, seed=9))
    tuner = api.autotune(ses, CHAOS_SPEC, seed=7)
    ses.submit(api.parse_workload("lublin", n_jobs=60, n_nodes=NODES,
                                  seed=3, load=1.0))
    if snapshot_at is not None:
        ses.step_until(snapshot_at)
        ses = api.SimSession.restore(ses.snapshot())
        tuner = ses.autotuner
        assert tuner is not None
    if step is None:
        ses.run_to_exhaustion()
    else:
        while ses.step(step):
            pass
    return result_dict(ses.result()), tuner.decisions


def test_decision_log_is_partition_invariant_under_chaos():
    ref, dec_ref = _chaos_tuned()
    assert dec_ref                          # the tuner actually fired
    for step in (1, 7):
        r, dec = _chaos_tuned(step=step)
        assert r == ref and dec == dec_ref


def test_decision_log_survives_snapshot_restore():
    ref, dec_ref = _chaos_tuned()
    r, dec = _chaos_tuned(snapshot_at=5000.0)
    assert r == ref and dec == dec_ref


def test_tuner_restore_in_fresh_process(tmp_path):
    ref, dec_ref = _chaos_tuned()
    ses = api.open_session(NODES, GREEDY_P)
    ses.attach_narrator(api.parse_narrator(CHAOS, seed=9))
    api.autotune(ses, CHAOS_SPEC, seed=7)
    ses.submit(api.parse_workload("lublin", n_jobs=60, n_nodes=NODES,
                                  seed=3, load=1.0))
    ses.step_until(5000.0)
    path = str(tmp_path / "snap.json")
    ses.snapshot().save(path)
    prog = (
        "import dataclasses, json, sys\n"
        "from repro.sched.session import SimSession\n"
        "ses = SimSession.restore(sys.argv[1])\n"
        "ses.run_to_exhaustion()\n"
        "d = dataclasses.asdict(ses.result())\n"
        "d.pop('sim_wall_s')\n"
        "print(json.dumps({'result': d, "
        "'decisions': ses.autotuner.decisions}))\n"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", prog, path],
                         capture_output=True, text=True, check=True, env=env)
    fresh = json.loads(out.stdout)
    assert fresh["result"] == json.loads(json.dumps(ref))
    assert fresh["decisions"] == json.loads(json.dumps(dec_ref))


def test_incumbent_pinned_tuner_is_bit_identical_to_untuned():
    """A tuner whose portfolio is only the incumbent can never swap — the
    live trajectory must be byte-for-byte the untuned run's, including
    across a snapshot/restore round trip."""
    def cell(tuned, snapshot_at=None):
        ses = _rack_failure_session(jobs=150, join_t=7000.0)
        if tuned:
            api.autotune(ses, "every=2000;rungs=2", seed=0)
        if snapshot_at is not None:
            ses.step_until(snapshot_at)
            ses = api.SimSession.restore(ses.snapshot())
        ses.run_to_exhaustion()
        return ses, result_dict(ses.result())

    _, ref = cell(tuned=False)
    ses, r = cell(tuned=True)
    assert r == ref
    assert ses.autotuner.decisions
    assert all(d["reason"] == "incumbent-best"
               for d in ses.autotuner.decisions)
    _, r2 = cell(tuned=True, snapshot_at=3000.0)
    assert r2 == ref


def test_live_promotion_beats_incumbent_fixed_run():
    """The bench scenario in miniature: the tuner swaps to the migration
    policy after the rack failure and ends with a strictly lower max
    stretch than the fixed incumbent."""
    fixed = _rack_failure_session(join_t=7000.0, jobs=150)
    fixed.run_to_exhaustion()
    ses = _rack_failure_session(join_t=7000.0, jobs=150)
    tuner = api.autotune(ses, SPEC, seed=3)
    ses.run_to_exhaustion()
    assert any(d["swapped"] for d in tuner.decisions)
    assert ses.policy_name == GREEDY_PM
    assert (ses.result(light=True).max_stretch
            < fixed.result(light=True).max_stretch)
    # decision records are wall-clock-free (bit-identical replays)
    for d in tuner.decisions:
        assert not any("wall" in k for k in d)


# --------------------------------------------------------------------------- #
# wiring: api facade, CLI, serve                                               #
# --------------------------------------------------------------------------- #
def test_autotune_facade_requires_named_policy():
    from repro.sched.engine import Engine, SimParams

    ses = _rack_failure_session()
    tuner = api.autotune(ses, "every=2000", seed=1)
    assert ses.autotuner is tuner and tuner.seed == 1
    # an ad-hoc composed Policy instance has no rebuildable reference —
    # the tuner could neither race nor restore it
    from repro.sched.components import (FCFSStart, OptMin, QueueSubmit,
                                        ReclaimNodes, compose)
    pol = compose("ad-hoc", QueueSubmit(), ReclaimNodes(), FCFSStart(),
                  OptMin())
    specs = api.make_trace(api.parse_workload("lublin", n_jobs=5,
                                              n_nodes=8, seed=0))
    anon = api.SimSession.from_engine(
        Engine(specs, pol, SimParams(n_nodes=8)))
    with pytest.raises(ValueError, match="rebuildable"):
        api.autotune(anon, "every=2000")


def _write_script(path, lines):
    with open(path, "w") as f:
        for ln in lines:
            f.write((ln if isinstance(ln, str) else json.dumps(ln)) + "\n")


def test_cli_session_autotune_and_tune_op(tmp_path, capsys):
    log = tmp_path / "decisions.jsonl"
    script = tmp_path / "script.jsonl"
    _write_script(script, [
        {"op": "submit", "workload": "lublin", "jobs": 80, "seed": 7,
         "load": 1.1},
        {"op": "inject", "kind": "fail", "t": 2050, "nodes": RACK},
        {"op": "inject", "kind": "join", "t": 6000, "nodes": RACK},
        {"op": "step_until", "t": 2500},
        {"op": "tune"},
        {"op": "run"},
        {"op": "result", "light": True},
    ])
    assert cli_main(["session", "--script", str(script),
                     "--policy", GREEDY_P, "--nodes", str(NODES),
                     "--autotune", "every=4000;horizon=2000;rungs=2;"
                     f"margin=0.01;dwell=0;policies={GREEDY_PM}",
                     "--decision-log", str(log)]) == 0
    lines = [json.loads(l) for l in capsys.readouterr().out.splitlines()]
    tune_line = next(l for l in lines if l["kind"] == "tune")
    assert tune_line["swapped"] is True
    assert tune_line["policy"] == GREEDY_PM
    logged = [json.loads(l) for l in log.read_text().splitlines()]
    assert logged and logged[0]["swapped"] is True


def test_cli_autotune_with_restore_refused(tmp_path, capsys):
    ses = _rack_failure_session()
    ses.step_until(1000.0)
    snap_path = str(tmp_path / "snap.json")
    ses.snapshot().save(snap_path)
    script = tmp_path / "script.jsonl"
    _write_script(script, [{"op": "run"}])
    assert cli_main(["session", "--script", str(script),
                     "--restore", snap_path,
                     "--autotune", "every=100"]) == 2
    assert "--autotune cannot be combined" in capsys.readouterr().err


def test_cli_tune_op_without_tuner_fails(tmp_path, capsys):
    script = tmp_path / "script.jsonl"
    _write_script(script, [{"op": "tune"}])
    assert cli_main(["session", "--script", str(script),
                     "--policy", "FCFS", "--nodes", "16"]) == 2
    assert "no autotuner attached" in capsys.readouterr().err


def test_cli_tune_subcommand(capsys):
    assert cli_main([
        "tune", "--policy", GREEDY_P, "--spec",
        f"every=1500;horizon=4000;rungs=2;margin=0.01;dwell=0;"
        f"policies={GREEDY_PM}",
        "--workload", "lublin", "--jobs", "120", "--nodes", str(NODES),
        "--loads", "1.1", "--seeds", "7",
        "--fail-at", "2050", "--fail-nodes", "8", "--join-at", "7000",
    ]) == 0
    out = capsys.readouterr().out
    assert "decision(s)" in out and "final policy" in out


def test_serve_open_with_autotune_and_tune_op(tmp_path):
    from repro.serve.protocol import MUTATING_OPS, ProtocolError
    from repro.serve.registry import SessionRegistry, SessionStore

    assert "tune" in MUTATING_OPS
    reg = SessionRegistry(SessionStore(str(tmp_path / "store")))
    reg.apply_mutating("t", "s0", "open", {
        "policy": GREEDY_P, "nodes": NODES,
        "autotune": "every=4000;horizon=2000;rungs=2;margin=0.01;"
                    f"dwell=0;policies={GREEDY_PM}"}, seq=0)
    reg.apply_mutating("t", "s0", "submit", {
        "workload": "lublin", "jobs": 80, "seed": 7, "load": 1.1,
        "nodes": NODES}, seq=1)
    reg.apply_mutating("t", "s0", "inject",
                       {"kind": "fail", "t": 2050, "nodes": RACK}, seq=2)
    reg.apply_mutating("t", "s0", "inject",
                       {"kind": "join", "t": 6000, "nodes": RACK}, seq=3)
    reg.apply_mutating("t", "s0", "step_until", {"t": 2500}, seq=4)
    resp = reg.apply_mutating("t", "s0", "tune", {}, seq=5)
    assert resp["swapped"] is True and resp["policy"] == GREEDY_PM
    # a session opened without a tuner refuses the op deterministically
    reg.apply_mutating("t", "plain", "open",
                       {"policy": "FCFS", "nodes": 16}, seq=0)
    with pytest.raises(ProtocolError, match="no autotuner"):
        reg.apply_mutating("t", "plain", "tune", {}, seq=1)
