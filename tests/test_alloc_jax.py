"""Batched JAX allocation backend vs the numpy kernels: bit-identity.

The contract mirrors the one ``alloc_kernels`` holds against
``alloc_reference``: under x64, every per-lane result of the batched
water-filling is bit-equal to ``maxmin_yields_csr`` on that lane's CSR
alone — padding (extra rows, columns, lanes) must never leak into a real
cell, and the lockstep batched sweep must reproduce the numpy sweep's
records exactly.  The last test is the acceptance grid: 100 seeded cells
through one jitted lockstep sweep.
"""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("jax", reason="the batched backend needs jax "
                    "(pip install -r requirements-dev.txt)")

from repro.core import alloc_jax
from repro.core.alloc_kernels import (CSRIncidence, avg_yields_csr, build_csr,
                                      maxmin_yields_csr)
from repro.sched.engine import Engine, SimParams
from repro.sched.sweep import grid, run_batched, run_grid
from repro.workloads.registry import WorkloadSpec, make_trace_ir

from conftest import result_dict

pytestmark = pytest.mark.skipif(not alloc_jax.has_jax(),
                                reason="jax present but not importable")


# --------------------------------------------------------------------------- #
# fixtures                                                                     #
# --------------------------------------------------------------------------- #
def random_instance(rng, max_width=30, max_nodes=12):
    """A random incidence: varied width, zero-need jobs, dead nodes,
    multiplicities > 1, possibly empty running set."""
    W = int(rng.integers(1, max_width + 1))
    N = int(rng.integers(1, max_nodes + 1))
    run = np.sort(rng.choice(W, int(rng.integers(0, W + 1)), replace=False))
    cpu = rng.choice([0.0, 0.25, 0.5, 1.0], W)
    alive = np.nonzero(rng.random(N) > 0.15)[0]
    if alive.size == 0:
        alive = np.array([0])
    mappings = [[] for _ in range(W)]
    for j in run:
        mappings[j] = list(rng.choice(alive, int(rng.integers(1, 5)),
                                      replace=True))
    inc = build_csr(cpu, mappings, N)
    active = np.zeros(W, dtype=bool)
    active[run] = True
    return inc, active


# --------------------------------------------------------------------------- #
# kernel parity                                                                #
# --------------------------------------------------------------------------- #
def test_maxmin_single_bit_equal():
    rng = np.random.default_rng(7)
    for _ in range(30):
        inc, active = random_instance(rng)
        got = alloc_jax.maxmin_yields_jax(inc, active)
        assert np.array_equal(got, maxmin_yields_csr(inc, active))


def test_maxmin_batch_padding_never_leaks():
    """Co-batched lanes, padded rows/cols and extra empty lanes must leave
    every real lane's yields bit-identical to its solo numpy solve."""
    rng = np.random.default_rng(11)
    insts = [random_instance(rng) for _ in range(12)]
    incs = [i for i, _ in insts]
    actives = [a for _, a in insts]
    N = max(i.n_nodes for i in incs)
    W = max(i.width for i in incs)
    # pad well beyond the minimal shape, plus 4 all-inactive lanes
    present, weight, active = alloc_jax.pad_batch(
        incs, actives, n_nodes=N + 5, width=W + 9, n_lanes=len(incs) + 4)
    y = alloc_jax.maxmin_yields_batch(present, weight, active)
    for b, (inc, act) in enumerate(insts):
        ref = maxmin_yields_csr(inc, act)
        assert np.array_equal(y[b, : inc.width], ref), f"lane {b} diverged"
        assert not y[b, inc.width:].any(), f"lane {b} padding got yields"
    assert not y[len(insts):].any(), "padding lanes got yields"


def test_maxmin_batch_composition_independent():
    """A lane's answer must not depend on what else is in the batch."""
    rng = np.random.default_rng(13)
    insts = [random_instance(rng) for _ in range(6)]
    incs = [i for i, _ in insts]
    actives = [a for _, a in insts]
    solo = []
    for inc, act in insts:
        p, w, a = alloc_jax.pad_batch([inc], [act])
        solo.append(alloc_jax.maxmin_yields_batch(p, w, a)[0])
    p, w, a = alloc_jax.pad_batch(incs, actives)
    together = alloc_jax.maxmin_yields_batch(p, w, a)
    for b, inc in enumerate(incs):
        assert np.array_equal(together[b, : inc.width],
                              solo[b][: inc.width])


def test_avg_backend_bit_equal():
    rng = np.random.default_rng(17)
    backend = alloc_jax.JaxAllocBackend()
    n_checked = 0
    for _ in range(20):
        inc, active = random_instance(rng)
        cols = np.nonzero(active)[0].astype(np.int64)
        if not cols.size:
            continue
        got = backend.allocate(inc, cols, "AVG")
        assert np.array_equal(got, avg_yields_csr(inc, cols))
        n_checked += 1
    assert n_checked >= 10


def test_backend_empty_running_set():
    inc = build_csr([0.5], [[]], 4)
    backend = alloc_jax.JaxAllocBackend()
    for opt in ("MIN", "AVG"):
        out = backend.allocate(inc, np.zeros(0, dtype=np.int64), opt)
        assert out.shape == (0,)
    with pytest.raises(ValueError):
        backend.allocate(inc, np.array([0]), "MAX")


def test_batched_allocator_mixed_opts():
    """One allocate_many round mixing MIN and AVG requests answers each
    bit-identically to the per-cell numpy kernels."""
    rng = np.random.default_rng(19)
    reqs, refs = [], []
    for k in range(8):
        inc, active = random_instance(rng)
        cols = np.nonzero(active)[0].astype(np.int64)
        opt = "AVG" if (k % 2 and cols.size) else "MIN"
        reqs.append((inc, cols, opt))
        if opt == "MIN":
            refs.append(maxmin_yields_csr(inc, active)[cols])
        else:
            refs.append(avg_yields_csr(inc, cols))
    outs = alloc_jax.BatchedAllocator().allocate_many(reqs)
    for got, ref in zip(outs, refs):
        assert np.array_equal(got, ref)


# --------------------------------------------------------------------------- #
# Pallas kernel                                                                #
# --------------------------------------------------------------------------- #
def test_pallas_matvec_bit_equal_csr():
    """The Pallas interpret kernel reproduces the sequential CSR matvec bit
    for bit (the adds-only formulation defeats XLA's FMA contraction)."""
    from jax.experimental import enable_x64

    from repro.kernels.alloc_matvec import alloc_matvec, alloc_matvec_ref

    rng = np.random.default_rng(23)
    incs_x = []
    B, N, W = 6, 10, 24
    weight = np.zeros((B, N, W))
    xs = np.zeros((B, W))
    for b in range(B):
        inc, active = random_instance(rng, max_width=W, max_nodes=N)
        _, w = alloc_jax.densify_csr(inc, n_nodes=N, width=W)
        weight[b] = w
        x = rng.random(W)
        xs[b] = x
        incs_x.append((inc, x))
    with enable_x64():
        got_pl = np.asarray(alloc_matvec(weight, xs, interpret=True))
        got_ref = np.asarray(alloc_matvec_ref(weight, xs))
    for b, (inc, x) in enumerate(incs_x):
        ref = inc.matvec(x[: inc.width].copy())
        assert np.array_equal(got_pl[b, : inc.n_nodes], ref)
        assert np.array_equal(got_ref[b, : inc.n_nodes], ref)


def test_maxmin_pallas_matvec_bit_equal():
    rng = np.random.default_rng(29)
    for _ in range(6):
        inc, active = random_instance(rng, max_width=16, max_nodes=8)
        got = alloc_jax.maxmin_yields_jax(inc, active, matvec="pallas")
        assert np.array_equal(got, maxmin_yields_csr(inc, active))


def test_ops_dispatch_alloc_matvec():
    """kernels.ops.alloc_matvec: ref and pallas backends agree bitwise."""
    from jax.experimental import enable_x64

    from repro.kernels import ops

    rng = np.random.default_rng(31)
    weight = rng.random((3, 6, 10))
    x = rng.random((3, 10))
    prev = ops.get_backend()
    try:
        with enable_x64():
            ops.set_backend("ref")
            a = np.asarray(ops.alloc_matvec(weight, x))
            ops.set_backend("pallas")
            b = np.asarray(ops.alloc_matvec(weight, x))
    finally:
        ops.set_backend(prev)
    assert np.array_equal(a, b)


# --------------------------------------------------------------------------- #
# stretch scatter (segment_sum)                                                #
# --------------------------------------------------------------------------- #
def test_node_usage_bit_equal_add_at():
    rng = np.random.default_rng(37)
    for _ in range(10):
        n_nodes = int(rng.integers(1, 16))
        k = int(rng.integers(0, 40))
        nodes = rng.integers(0, n_nodes, k)
        vals = rng.random(k)
        ref = np.zeros(n_nodes)
        np.add.at(ref, nodes, vals)
        got = alloc_jax.node_usage(nodes, vals, n_nodes)
        assert np.array_equal(got, ref)


def test_node_usage_batch_padding():
    rng = np.random.default_rng(41)
    n_nodes, B, K = 9, 5, 20
    nodes = np.full((B, K), n_nodes, dtype=np.int64)   # sentinel = padding
    vals = np.zeros((B, K))
    refs = []
    for b in range(B):
        k = int(rng.integers(0, K))
        nodes[b, :k] = rng.integers(0, n_nodes, k)
        vals[b, :k] = rng.random(k)
        ref = np.zeros(n_nodes)
        np.add.at(ref, nodes[b, :k], vals[b, :k])
        refs.append(ref)
    got = alloc_jax.node_usage_batch(nodes, vals, n_nodes)
    for b in range(B):
        assert np.array_equal(got[b], refs[b])


# --------------------------------------------------------------------------- #
# engine + sweep integration                                                   #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", ["GreedyP */OPT=MIN", "Greedy */OPT=AVG"])
def test_engine_backend_bit_identical(policy):
    tr = make_trace_ir(WorkloadSpec("lublin", n_jobs=60, n_nodes=16, seed=3))
    base = Engine(tr, policy, SimParams(n_nodes=16)).run()
    jaxed = Engine(tr, policy, SimParams(n_nodes=16),
                   alloc_backend=alloc_jax.JaxAllocBackend()).run()
    assert result_dict(base) == result_dict(jaxed)


_OUTCOME_KEYS = (
    "max_stretch", "mean_stretch", "makespan", "underutilization",
    "n_pmtn", "n_mig", "pmtn_per_job", "mig_per_job", "pmtn_per_hour",
    "mig_per_hour", "bytes_moved_gb", "bandwidth_gbps", "events",
    "hit_max_events", "final_time", "trace_fingerprint",
)


def _outcomes(res):
    return [{k: r[k] for k in _OUTCOME_KEYS} for r in res.records]


def test_run_batched_matches_run_grid():
    ws = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=s)
          for s in range(4)]
    cells = grid(ws, ["GreedyP */OPT=MIN"], ["baseline", "rack_failure"])
    ref = run_grid(cells, compute_bound=True)
    got = run_batched(cells, compute_bound=True)
    assert _outcomes(got) == _outcomes(ref)
    assert all(r["backend"] == "jax" for r in got.records)
    assert all(g["bound"] == r["bound"]
               for g, r in zip(got.records, ref.records))


def test_run_grid_backend_arg():
    cells = grid([WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=0)],
                 ["GreedyP */OPT=MIN"])
    ref = run_grid(cells)
    got = run_grid(cells, backend="jax")
    assert _outcomes(got) == _outcomes(ref)
    with pytest.raises(ValueError):
        run_grid(cells, backend="cuda")


def test_run_batched_mixed_policies_and_batch_baselines():
    """Lanes that never allocate (FCFS/EASY) and OPT=AVG lanes coexist in
    one lockstep schedule without deadlock or divergence."""
    ws = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=s)
          for s in range(2)]
    policies = ["FCFS", "EASY", "GreedyP */OPT=MIN", "Greedy */OPT=AVG"]
    cells = grid(ws, policies, ["baseline"])
    ref = run_grid(cells)
    got = run_batched(cells)
    assert _outcomes(got) == _outcomes(ref)


def test_run_batched_propagates_errors():
    """A lane that raises must surface its exception on the driver thread
    (and release the other lanes) instead of deadlocking the lockstep."""
    cells = [Cell(WorkloadSpec("lublin", n_jobs=10, n_nodes=4, seed=0),
                  "GreedyP */OPT=MIN")
             for _ in range(2)]
    bad = [Cell(WorkloadSpec("lublin", n_jobs=10, n_nodes=4, seed=0),
                "NoSuchPolicy")]
    with pytest.raises(ValueError, match="NoSuchPolicy"):
        run_batched(bad + cells)


from repro.sched.sweep import Cell  # noqa: E402  (used above)


def test_acceptance_100_seed_grid_single_jitted_sweep():
    """The ISSUE acceptance criterion: a 100-cell seeded grid (one workload
    family × one policy × 100 seeds) end-to-end through the batched backend
    in one lockstep sweep, per-cell mean/max stretch matching the numpy
    sweep exactly (stronger than the required 1e-9 relative tolerance)."""
    ws = [WorkloadSpec("lublin", n_jobs=25, n_nodes=8, seed=s)
          for s in range(100)]
    cells = grid(ws, ["GreedyP */OPT=MIN"], ["baseline"])
    assert len(cells) == 100
    ref = run_grid(cells)
    got = run_batched(cells)
    for g, r in zip(got.records, ref.records):
        assert g["mean_stretch"] == r["mean_stretch"]
        assert g["max_stretch"] == r["max_stretch"]
    assert _outcomes(got) == _outcomes(ref)
