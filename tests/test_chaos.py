"""Robustness suite: chaos narrator, estimate-vs-truth, cancel/resize,
inject contradiction guards, supervised sweeps, cache corruption.

* narrator determinism: same seed → bit-identical SimResult, including
  across a mid-run snapshot/restore (same and *fresh* process) with chaos
  streams mid-flight;
* snapshot taken inside an open failure window (node down, repair pending)
  replays the repair bit-identically;
* estimate vs truth: policies schedule on ``proc_time``, the engine
  executes ``proc_truth`` — demonstrated directly on a noisy Trace and on a
  Table-1 policy grid through the ``ptime_noise`` scenario;
* cancel/resize injections keep pool and integral accounting consistent;
* ``SimSession.inject`` rejects contradictory events with errors naming
  the node/jid and time;
* supervised ``run_grid``: a grid with a raising cell and a timing-out
  cell still completes, retries on fresh workers, quarantines the losers;
* ``RecordCache``: a truncated on-disk cache is a warning + miss, never a
  crash, and quarantined records are never cached.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import result_dict as _result_dict
from repro.core.state import S_CANCELLED, S_COMPLETED
from repro.sched.cluster import ClusterEvent
from repro.sched.engine import Engine, SimParams
from repro.sched.narrator import (Narrator, list_streams, narrator_docs,
                                  parse_narrator)
from repro.sched.session import SimSession, open_session
from repro.sched.sweep import Cell, RecordCache, grid, run_grid
from repro.workloads.registry import WorkloadSpec, make_trace, make_trace_ir

W = WorkloadSpec("lublin", n_jobs=60, n_nodes=16, seed=0)
CHAOS = "breakdown(mtbf=6e3,repair=8e2)+cancel(rate=1e-4)+noise(sigma=0.3)"


def _chaos_session(policy="GreedyP */OPT=MIN", spec=CHAOS, seed=7,
                   workload=W):
    ses = open_session(workload.n_nodes, policy)
    ses.attach_narrator(parse_narrator(spec, seed=seed))
    ses.submit(make_trace(workload))
    return ses


# --------------------------------------------------------------------------- #
# narrator: grammar, registry, determinism                                     #
# --------------------------------------------------------------------------- #
def test_narrator_grammar_and_registry():
    for kind in ("breakdown", "cancel", "malleable", "noise"):
        assert kind in list_streams()
        assert narrator_docs()[kind]
    nar = parse_narrator("breakdown(mtbf=2e4,repair=2e3)+noise", seed=3)
    assert len(nar.streams) == 2 and nar.needs_cluster_events()
    assert not parse_narrator("noise", seed=0).needs_cluster_events()
    with pytest.raises(ValueError, match="unknown narrator stream"):
        parse_narrator("gremlins")
    with pytest.raises(ValueError, match="key=value"):
        parse_narrator("breakdown(2e4)")
    with pytest.raises(ValueError):
        parse_narrator("cancel(rate=-1)")


def test_narrator_same_seed_bit_identical():
    a = _chaos_session().run()
    b = _chaos_session().run()
    assert _result_dict(a) == _result_dict(b)
    # the chaos actually happened: withdrawn jobs and noisy truth
    assert a.n_cancelled >= 1
    assert len(a.completions) == W.n_jobs - a.n_cancelled


def test_narrator_bit_identity_across_step_boundaries():
    """Where step_until boundaries fall must not change what the narrator
    does (lazy, boundary-safe firing)."""
    ref = _chaos_session().run()
    ses = _chaos_session()
    for t in np.linspace(0.0, 2.0e5, 23):
        ses.step_until(float(t))
    r = ses.run()
    assert _result_dict(r) == _result_dict(ref)


def test_narrator_snapshot_restore_mid_chaos_bit_identical(tmp_path):
    ref = _chaos_session().run()
    ses = _chaos_session()
    ses.step_until(2.0e4)
    path = str(tmp_path / "chaos-snap.json")
    ses.snapshot().save(path)
    restored = SimSession.restore(path)
    assert restored.narrator is not None
    r = restored.run()
    assert _result_dict(r) == _result_dict(ref)


def test_narrator_snapshot_restore_fresh_process(tmp_path):
    """The acceptance criterion: the same narrator seed is bit-identical
    across a mid-run snapshot restored in a *fresh* interpreter."""
    ref = _chaos_session().run()
    ses = _chaos_session()
    ses.step_until(2.0e4)
    path = str(tmp_path / "chaos-snap.json")
    ses.snapshot().save(path)
    prog = (
        "import dataclasses, json, sys\n"
        "from repro.sched.session import SimSession\n"
        "r = SimSession.restore(sys.argv[1]).run()\n"
        "d = dataclasses.asdict(r)\n"
        "d.pop('sim_wall_s')\n"
        "print(json.dumps(d))\n"
    )
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", prog, path],
                         capture_output=True, text=True, check=True, env=env)
    fresh = json.loads(out.stdout)
    assert fresh == json.loads(json.dumps(_result_dict(ref)))


def test_snapshot_inside_open_failure_window_replays_repair():
    """Snapshot while a node is down with its repair still pending: the
    restored session replays the repair (and everything after) bit-
    identically, and the cluster heals."""
    spec = "breakdown(mtbf=2e3,repair=3e3)"
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.attach_narrator(parse_narrator(spec, seed=11))
    ses.submit(make_trace(W))
    while ses.observe()["alive_nodes"] == 16 and not ses.exhausted:
        ses.step(5)
    assert ses.observe()["alive_nodes"] < 16   # inside the failure window
    snap = ses.snapshot()
    ref = ses.run()
    restored = SimSession.restore(snap)
    assert restored.observe()["alive_nodes"] < 16
    r = restored.run()
    assert _result_dict(r) == _result_dict(ref)
    assert restored.observe()["alive_nodes"] == 16   # repair replayed


# --------------------------------------------------------------------------- #
# estimate vs truth                                                            #
# --------------------------------------------------------------------------- #
def test_estimate_vs_truth_direct_trace():
    """The engine executes ``proc_truth``; policies observe ``proc_time``.
    Doubling the truth of every job must stretch the schedule while the
    estimate (and therefore the policy's view) stays fixed."""
    tr = make_trace_ir(W)
    noisy = tr.replace(proc_truth=tr.proc_time * 2.0)
    params = SimParams(n_nodes=16)
    clean = Engine(tr, "GreedyP */OPT=MIN", params).run()
    slow = Engine(noisy, "GreedyP */OPT=MIN", params).run()
    assert slow.makespan > clean.makespan
    assert all(slow.completions[j] >= clean.completions[j]
               for j in clean.completions)
    # truth round-trips through the frozen IR and its fingerprint
    assert noisy.fingerprint != tr.fingerprint
    assert tr.replace(proc_truth=None).fingerprint == tr.fingerprint


def test_estimate_vs_truth_table1_grid():
    """Clairvoyant vs noisy stretch on a Table-1 policy grid: the
    ``ptime_noise`` scenario perturbs only the truth column, every cell
    completes, and the noise moves the measured stretch."""
    cells = grid([W], ["GreedyP */OPT=MIN", "GreedyPM */per/OPT=MIN/MINVT=600"],
                 ["baseline", "ptime_noise"])
    res = run_grid(cells, n_workers=1)
    assert res.n_cells == 4
    by = {(r["policy"], r["scenario"]): r for r in res.records}
    for pol in ("GreedyP */OPT=MIN", "GreedyPM */per/OPT=MIN/MINVT=600"):
        clean = by[(pol, "baseline")]
        noisy = by[(pol, "ptime_noise")]
        assert not clean["hit_max_events"] and not noisy["hit_max_events"]
        assert noisy["mean_stretch"] != clean["mean_stretch"]
        # same jobs, different executed times -> different fingerprints
        assert noisy["trace_fingerprint"] == clean["trace_fingerprint"]


def test_noise_stream_rewrites_truth_only():
    ses = _chaos_session(spec="noise(sigma=0.4)")
    st = ses.engine.state
    assert int((st.proc_truth != st.proc_time).sum()) == W.n_jobs
    assert ses.observe()["n_noisy"] == W.n_jobs
    r = ses.run()
    assert len(r.completions) == W.n_jobs
    # noise works under batch policies too (no cluster events involved)
    bses = _chaos_session(policy="EASY", spec="noise(sigma=0.4)")
    assert len(bses.run().completions) == W.n_jobs
    bses2 = open_session(16, "EASY")
    with pytest.raises(ValueError, match="batch"):
        bses2.attach_narrator(parse_narrator("breakdown", seed=0))


# --------------------------------------------------------------------------- #
# cancel / resize injections                                                   #
# --------------------------------------------------------------------------- #
def test_cancel_injection_accounting():
    specs = make_trace(W)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[5].release + 1.0)
    victim = next(i for i in ses.engine.state.in_system_indices())
    jid = ses.engine.state.specs[victim].jid
    ses.inject(ClusterEvent(ses.now + 10.0, "cancel", jids=(jid,)))
    r = ses.run()
    st = ses.engine.state
    assert int(st.status[victim]) == S_CANCELLED
    assert r.n_cancelled == 1
    assert len(r.completions) == W.n_jobs - 1
    assert jid not in r.completions
    # the pool healed: nothing left running, no deadlock raise above
    assert st.running_indices().size == 0


def test_resize_injection_changes_width():
    specs = make_trace(W)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[5].release + 1.0)
    victim = next(i for i in ses.engine.state.in_system_indices())
    jid = ses.engine.state.specs[victim].jid
    old_n = ses.engine.state.specs[victim].n_tasks
    new_n = 16 if old_n < 16 else 1
    ses.inject(ClusterEvent(ses.now + 10.0, "resize", jids=(jid,),
                            value=float(new_n)))
    r = ses.run()
    assert ses.engine.state.specs[victim].n_tasks == new_n
    assert len(r.completions) == W.n_jobs       # resize never loses the job


def test_allocation_survives_node_death_under_running_jobs():
    """Nodes dying under running jobs re-water-fill onto survivors instead
    of raising; the cell still drains completely."""
    specs = make_trace(W)
    ses = open_session(16, "GreedyPM */per/OPT=MIN/MINVT=600")
    ses.submit(specs)
    ses.step_until(specs[10].release + 1.0)
    assert ses.observe()["n_running"] > 0
    t = ses.now
    ses.inject(ClusterEvent(t + 5.0, "fail", (0, 1, 2, 3, 4, 5)))
    ses.inject(ClusterEvent(t + 4000.0, "join", (0, 1, 2, 3, 4, 5)))
    r = ses.run()
    assert len(r.completions) == W.n_jobs


# --------------------------------------------------------------------------- #
# inject contradiction guards                                                  #
# --------------------------------------------------------------------------- #
def test_inject_rejects_contradictory_node_events():
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(make_trace(W))
    ses.step(2)
    t = ses.now + 10.0
    ses.inject(ClusterEvent(t, "fail", (3,)))
    with pytest.raises(ValueError, match=r"node 3 .*already dead"):
        ses.inject(ClusterEvent(t + 1.0, "fail", (3,)))
    with pytest.raises(ValueError, match=r"node 5 .*alive"):
        ses.inject(ClusterEvent(t + 1.0, "join", (5,)))
    # the repair heals the projection: a second failure is legal again
    ses.inject(ClusterEvent(t + 2.0, "join", (3,)))
    ses.inject(ClusterEvent(t + 3.0, "fail", (3,)))


def test_inject_rejects_contradictory_job_events():
    specs = make_trace(W)
    ses = open_session(16, "GreedyP */OPT=MIN")
    ses.submit(specs)
    ses.step_until(specs[5].release + 1.0)
    st = ses.engine.state
    victim = next(iter(st.in_system_indices()))
    jid = st.specs[victim].jid
    t = ses.now + 10.0
    with pytest.raises(ValueError, match="unknown job id 987654"):
        ses.inject(ClusterEvent(t, "cancel", jids=(987654,)))
    done = next((s.jid for i, s in enumerate(st.specs)
                 if int(st.status[i]) == S_COMPLETED), None)
    if done is not None:
        with pytest.raises(ValueError, match="already completed"):
            ses.inject(ClusterEvent(t, "cancel", jids=(done,)))
    ses.inject(ClusterEvent(t, "cancel", jids=(jid,)))
    with pytest.raises(ValueError, match=str(jid)):
        ses.inject(ClusterEvent(t + 1.0, "cancel", jids=(jid,)))


# --------------------------------------------------------------------------- #
# supervised sweeps: timeout, retry, quarantine                                #
# --------------------------------------------------------------------------- #
def test_supervised_grid_completes_around_bad_cells():
    """The resilience acceptance criterion: a grid with a raising cell and
    a timing-out cell completes the others, retries the losers on fresh
    workers, and emits quarantine records."""
    ok = WorkloadSpec("lublin", n_jobs=25, n_nodes=16, seed=0)
    slow = WorkloadSpec("lublin", n_jobs=6000, n_nodes=16, seed=1)
    cells = (grid([ok], ["FCFS", "GreedyP */OPT=MIN"])
             + grid([ok], ["NOSUCH-POLICY"])          # raises in the worker
             + grid([slow], ["GreedyP */OPT=MIN"]))   # blows the budget
    res = run_grid(cells, n_workers=2, timeout_s=1.0, retries=1)
    assert res.n_cells == 4
    assert res.n_quarantined == 2
    healthy = [r for r in res.records if not r.get("quarantined")]
    assert {r["policy"] for r in healthy} == {"FCFS", "GreedyP */OPT=MIN"}
    ref = run_grid(grid([ok], ["FCFS", "GreedyP */OPT=MIN"]), n_workers=1)
    for got, want in zip(healthy, ref.records):
        for k in want:
            if k not in ("wall_s", "sim_wall_s"):
                assert got[k] == want[k], k
    bad = {r["policy"]: r for r in res.quarantined}
    assert "NOSUCH-POLICY" in bad["NOSUCH-POLICY"]["error"]
    assert bad["NOSUCH-POLICY"]["attempts"] == 2      # retried once
    slow_rec = bad["GreedyP */OPT=MIN"]
    assert "timeout" in slow_rec["error"]
    assert slow_rec["attempts"] == 2
    # quarantined cells carry no metrics and are skipped by summaries
    assert "mean_stretch" not in slow_rec
    assert set(res.summary(by="policy")) == {"FCFS", "GreedyP */OPT=MIN"}


def test_supervised_matches_plain_on_healthy_grid():
    cells = grid([WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=2)],
                 ["FCFS", "GreedyP */OPT=MIN"], ["baseline", "rack_failure"])
    plain = run_grid(cells, n_workers=1)
    sup = run_grid(cells, n_workers=2, retries=1)
    assert sup.n_quarantined == 0
    for a, b in zip(plain.records, sup.records):
        for k in a:
            if k not in ("wall_s", "sim_wall_s"):
                assert a[k] == b[k], k


# --------------------------------------------------------------------------- #
# RecordCache robustness                                                       #
# --------------------------------------------------------------------------- #
def test_record_cache_truncated_file_is_a_miss(tmp_path, capsys):
    path = str(tmp_path / "cache.json")
    w = WorkloadSpec("lublin", n_jobs=15, n_nodes=16, seed=0)
    RecordCache(path).sweep([w], ["FCFS"], n_workers=1, compute_bound=False)
    raw = open(path).read()
    open(path, "w").write(raw[: len(raw) // 2])     # killed mid-write
    cache = RecordCache(path)                       # warns, never raises
    assert len(cache) == 0
    assert "unreadable" in capsys.readouterr().err
    recs = cache.sweep([w], ["FCFS"], n_workers=1, compute_bound=False)
    assert len(recs) == 1 and "mean_stretch" in recs[0]
    assert len(RecordCache(path)) == 1              # healed atomically


def test_record_cache_skips_individually_malformed_records(tmp_path, capsys):
    path = str(tmp_path / "cache.json")
    w = WorkloadSpec("lublin", n_jobs=15, n_nodes=16, seed=0)
    RecordCache(path).sweep([w], ["FCFS"], n_workers=1, compute_bound=False)
    payload = json.loads(open(path).read())
    payload["records"][0]["params"] = 42            # key-building blows up
    payload["records"].append("not-a-record")
    open(path, "w").write(json.dumps(payload))
    cache = RecordCache(path)
    assert len(cache) == 0
    assert "malformed" in capsys.readouterr().err
    # wrong-schema (valid JSON, foreign file) still refuses loudly
    foreign = str(tmp_path / "foreign.json")
    open(foreign, "w").write(json.dumps({"schema": "something/else"}))
    with pytest.raises(ValueError, match="refusing"):
        RecordCache(foreign)


def test_record_cache_never_caches_quarantined(tmp_path):
    path = str(tmp_path / "cache.json")
    w = WorkloadSpec("lublin", n_jobs=15, n_nodes=16, seed=0)
    recs = RecordCache(path).sweep([w], ["FCFS", "NOSUCH-POLICY"],
                                   n_workers=1, compute_bound=False,
                                   timeout_s=30.0, retries=0)
    assert len(recs) == 2
    quar = [r for r in recs if r.get("quarantined")]
    assert len(quar) == 1 and quar[0]["policy"] == "NOSUCH-POLICY"
    assert len(RecordCache(path)) == 1              # only the healthy record


# --------------------------------------------------------------------------- #
# the streaming CLI end to end                                                 #
# --------------------------------------------------------------------------- #
def test_cli_narrator_runs_bit_identical(tmp_path):
    from repro.__main__ import main as cli_main
    script = tmp_path / "script.jsonl"
    script.write_text(
        '{"op": "submit", "workload": "lublin", "jobs": 40, "nodes": 16}\n'
        '{"op": "run"}\n'
        '{"op": "result"}\n')
    outs = []
    for run in ("a", "b"):
        metrics = str(tmp_path / f"metrics-{run}.jsonl")
        rc = cli_main(["session", "--script", str(script),
                       "--policy", "GreedyP */OPT=MIN", "--nodes", "16",
                       "--narrator", CHAOS, "--narrator-seed", "7",
                       "--metrics", metrics])
        assert rc == 0
        lines = [json.loads(l) for l in open(metrics)]
        for rec in lines:
            rec.pop("sim_wall_s", None)
        outs.append(lines)
    assert outs[0] == outs[1]
    assert outs[0][-1]["kind"] == "result"
