"""End-to-end system tests: the paper's qualitative claims at mini scale,
the serving stack, and the equipartition theory checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.bound import max_stretch_lower_bound
from repro.core.equipartition import (equipartition_schedule, max_stretch,
                                      thm4_instance)
from repro.models import backbone
from repro.sched.simulator import SimParams, simulate
from repro.train.serve import BatchedServer, Request, ServeConfig
from repro.workloads.hpc2n import hpc2n_like_trace, parse_swf
from repro.workloads.lublin import lublin_trace, scale_to_load


# --------------------------------------------------------------------------- #
# paper claims at mini scale                                                   #
# --------------------------------------------------------------------------- #
def test_dfrs_beats_batch_by_an_order_of_magnitude():
    specs = lublin_trace(n_jobs=150, n_nodes=32, seed=11)
    specs = scale_to_load(specs, 32, 0.7)
    params = SimParams(n_nodes=32)
    lb = max_stretch_lower_bound(specs, 32)
    easy = simulate(specs, "EASY", params).max_stretch / lb
    best = simulate(specs, "GreedyPM */per/OPT=MIN/MINVT=600",
                    params).max_stretch / lb
    assert best * 10 <= easy
    assert best < 50          # "close to the offline bound in practice"


def test_minvt_prevents_mcb8_thrashing():
    specs = lublin_trace(n_jobs=120, n_nodes=32, seed=5)
    specs = scale_to_load(specs, 32, 0.7)
    params = SimParams(n_nodes=32)
    with_grace = simulate(specs, "MCB8 */OPT=MIN/MINVT=600", params)
    without = simulate(specs, "MCB8 */OPT=MIN", params)
    assert with_grace.mig_per_job <= without.mig_per_job + 1e-9


def test_equipartition_thm4():
    """EQUIPARTITION hits max stretch exactly n on the adversarial instance;
    the alternative schedule stays near 2 + ln(n-1)."""
    for n in (5, 9):
        rel, proc = thm4_instance(n)
        comp = equipartition_schedule(rel, proc)
        assert max_stretch(rel, proc, comp) == pytest.approx(n, rel=1e-6)
        alt = 2.0 + sum(1.0 / i for i in range(2, n - 1 + 1))
        assert n / alt > 1.5   # the competitive gap is real


# --------------------------------------------------------------------------- #
# workloads                                                                    #
# --------------------------------------------------------------------------- #
def test_swf_parsing():
    text = "; comment line\n1 0 -1 3600 64 -1 512 64 7200 1024 -1 1 1 1 1 0 1 -1\n"
    jobs = parse_swf(text)
    assert len(jobs) == 1
    j = jobs[0]
    assert j.jid == 1 and j.run == 3600 and j.procs == 64
    assert j.used_mem_kb == 512 and j.req_mem_kb == 1024


def test_hpc2n_like_preprocessing_rules():
    """SS5.3.1: even-proc small-mem jobs become multithreaded 100%-CPU tasks;
    odd-proc / big-mem jobs become 50%-CPU per-proc tasks."""
    specs = hpc2n_like_trace(n_jobs=200, seed=0)
    assert all(s.mem_req >= 0.10 - 1e-9 for s in specs)
    assert all(s.cpu_need in (0.5, 1.0) for s in specs)
    assert any(s.cpu_need == 1.0 for s in specs)
    assert any(s.cpu_need == 0.5 for s in specs)


def test_lublin_statistics():
    specs = lublin_trace(n_jobs=400, n_nodes=128, seed=0)
    sizes = np.array([s.n_tasks for s in specs])
    assert (sizes == 1).mean() > 0.1          # serial fraction
    mems = np.array([s.mem_req for s in specs])
    assert ((np.isclose(mems, 0.1)).mean() > 0.35)   # 55% at 10% mem
    assert sizes.max() <= 128


# --------------------------------------------------------------------------- #
# serving consistency                                                          #
# --------------------------------------------------------------------------- #
def test_server_matches_plain_decode():
    cfg = get_reduced("smollm-360m")
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, ServeConfig(slots=2, cache_len=64))
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i, dtype=np.int32),
                    max_new=5) for i in range(4)]
    for r in reqs:
        srv.submit(r)
    for _ in range(60):
        if not srv.queue and all(r is None for r in srv.slot_req):
            break
        srv.step()
    assert all(r.done for r in reqs)
    # reference: single-request greedy decode
    req = reqs[1]
    caches = backbone.init_cache(cfg, 1, 64)
    lg, caches = backbone.prefill(
        cfg, params, {"tokens": jnp.asarray(req.prompt)[None]}, caches)
    toks = [int(jnp.argmax(lg[0]))]
    pos = len(req.prompt)
    for _ in range(4):
        lg, caches = backbone.decode_step(
            cfg, params, jnp.array([toks[-1]], jnp.int32), caches,
            jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert req.out == toks
