"""repro.api facade + ``python -m repro`` CLI + compat-shim tests."""
import dataclasses

from conftest import result_dict as _result_dict
import json
import os
import warnings

import pytest

from repro import api
from repro.__main__ import main as cli_main
from repro.sched import _compat
from repro.sched.engine import Engine, SimParams
from repro.workloads.registry import WorkloadSpec, make_trace

W_SMALL = WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=0)


# --------------------------------------------------------------------------- #
# facade                                                                       #
# --------------------------------------------------------------------------- #
def test_api_simulate_workloadspec_matches_engine():
    r = api.simulate(W_SMALL, "GreedyP */OPT=MIN")
    direct = Engine(make_trace(W_SMALL), "GreedyP */OPT=MIN",
                    SimParams(n_nodes=16)).run()
    assert _result_dict(r) == _result_dict(direct)


def test_api_simulate_scenario_and_param_overrides():
    r = api.simulate(W_SMALL, "/per/OPT=MIN", scenario="rack_failure",
                     period=300.0)
    assert set(r.completions) == set(range(30))
    base = api.simulate(W_SMALL, "/per/OPT=MIN", period=6000.0)
    assert r.events != base.events


def test_api_simulate_raw_specs_needs_n_nodes():
    specs = make_trace(W_SMALL)
    with pytest.raises(ValueError, match="n_nodes"):
        api.simulate(specs, "FCFS")
    r = api.simulate(specs, "FCFS", n_nodes=16)
    assert r.policy == "FCFS"


def test_api_simulate_rejects_scenario_plus_events():
    with pytest.raises(ValueError, match="not both"):
        api.simulate(W_SMALL, "FCFS", scenario="baseline",
                     cluster_events=[api.ClusterEvent(1.0, "fail", (0,))])


def test_api_list_policies_surface():
    info = api.list_policies()
    assert len(info["table1"]) == 14
    assert info["n_paper_space"] == 116
    assert "EASY+OPT=MIN" in info["registered"]
    assert set(info["components"]) == {"submit", "complete", "periodic", "opt"}
    full = api.list_policies(include_paper_space=True)
    assert len(full["paper_space"]) == 116


def test_api_sweep_plain(tmp_path):
    path = str(tmp_path / "art.json")
    res = api.sweep([W_SMALL], ["FCFS", "GreedyP */OPT=MIN"],
                    n_workers=1, json_path=path)
    assert res.n_cells == 2
    assert json.loads(open(path).read())["schema"] == "repro.sweep/v1"


def test_api_sweep_cache_resumes_without_resimulating(tmp_path, monkeypatch):
    cache = str(tmp_path / "cache.json")
    res = api.sweep([W_SMALL], ["FCFS", "EASY"], cache_path=cache,
                    n_workers=1)
    assert res.n_cells == 2 and os.path.exists(cache)

    import repro.sched.sweep as sweep_mod

    def boom(*a, **kw):
        raise AssertionError("cache miss: run_grid called on a warm cache")

    monkeypatch.setattr(sweep_mod, "run_grid", boom)
    warm = api.sweep([W_SMALL], ["FCFS", "EASY"], cache_path=cache,
                     n_workers=1)
    assert [r["policy"] for r in warm.records] == ["FCFS", "EASY"]
    for a, b in zip(res.records, warm.records):
        assert a == b


def test_api_simulate_scenario_seed_is_respected():
    """seed= overrides the workload's own seed for the scenario script."""
    w = WorkloadSpec("lublin", n_jobs=60, n_nodes=16, seed=0, load=0.9)
    a = api.simulate(w, "GreedyP */OPT=MIN", scenario="rolling_failures")
    b = api.simulate(w, "GreedyP */OPT=MIN", scenario="rolling_failures",
                     seed=w.seed)
    assert _result_dict(a) == _result_dict(b)   # default = w.seed
    outcomes = {api.simulate(w, "GreedyP */OPT=MIN",
                             scenario="rolling_failures", seed=s).makespan
                for s in range(6)}
    assert len(outcomes) > 1        # varying seed= moves the failure script


def test_record_cache_simulates_equivalent_spellings_once():
    from repro.sched.sweep import RecordCache, _run_cell
    import repro.sched.sweep as sweep_mod

    calls = []
    orig = _run_cell

    def counting(task):
        calls.append(task[1].policy)
        return orig(task)

    cache = RecordCache()
    try:
        sweep_mod._run_cell = counting
        recs = cache.sweep([W_SMALL], ["Greedy *", "Greedy */OPT=MIN"],
                           n_workers=1, compute_bound=False)
    finally:
        sweep_mod._run_cell = orig
    assert len(calls) == 1            # one canonical cell simulated
    # each returned record mirrors its *requested* spelling + want-order cell
    assert [r["policy"] for r in recs] == ["Greedy *", "Greedy */OPT=MIN"]
    assert [r["cell"] for r in recs] == [0, 1]
    a, b = ({k: v for k, v in r.items() if k not in ("policy", "cell")}
            for r in recs)
    assert a == b                     # same simulated cell underneath


def test_record_cache_params_template_is_part_of_identity(tmp_path):
    """Different SimParams templates must not alias to one cached record."""
    cache = str(tmp_path / "c.json")
    a = api.sweep([W_SMALL], ["GreedyP */OPT=MIN"], cache_path=cache,
                  params=api.SimParams(stretch_tau=10.0), n_workers=1)
    b = api.sweep([W_SMALL], ["GreedyP */OPT=MIN"], cache_path=cache,
                  params=api.SimParams(stretch_tau=100.0), n_workers=1)
    assert a.records[0]["max_stretch"] != b.records[0]["max_stretch"]
    # both templates now live in the cache; re-asking either is a hit
    again = api.sweep([W_SMALL], ["GreedyP */OPT=MIN"], cache_path=cache,
                      params=api.SimParams(stretch_tau=10.0), n_workers=1)
    assert again.records[0]["max_stretch"] == a.records[0]["max_stretch"]


def test_record_cache_refuses_foreign_json(tmp_path):
    from repro.sched.sweep import RecordCache
    art = tmp_path / "artifact.json"
    res = api.run_grid(api.grid([W_SMALL], ["FCFS"]), n_workers=1)
    res.save_json(str(art))           # a repro.sweep/v1 artifact, not a cache
    with pytest.raises(ValueError, match="record cache"):
        RecordCache(str(art))
    assert json.loads(art.read_text())["schema"] == "repro.sweep/v1"  # intact


def test_api_sweep_cache_canonicalizes_scenario_chain_spellings(tmp_path,
                                                                monkeypatch):
    cache = str(tmp_path / "cache.json")
    api.sweep([W_SMALL], ["FCFS"], ["rack_failure+arrival_burst"],
              cache_path=cache, n_workers=1)

    import repro.sched.sweep as sweep_mod
    monkeypatch.setattr(
        sweep_mod, "run_grid",
        lambda *a, **kw: pytest.fail("equivalent chain spelling missed"))
    warm = api.sweep([W_SMALL], ["FCFS"], ["rack_failure + arrival_burst"],
                     cache_path=cache, n_workers=1)
    # served from cache, reported under the spelling this caller asked for
    assert warm.records[0]["scenario"] == "rack_failure + arrival_burst"


def test_api_workload_kinds_is_live_view():
    """Kinds registered after import appear in api.WORKLOAD_KINDS."""
    from repro.workloads import registry as reg
    name = "test-live-kind"
    if name not in reg.list_workloads():
        @api.register_workload(name, doc="live-view regression kind")
        def _live(spec):
            return api.make_trace_ir(api.WorkloadSpec(
                "lublin", n_jobs=spec.n_jobs, n_nodes=spec.n_nodes,
                seed=spec.seed))
    assert name in api.WORKLOAD_KINDS
    assert name in reg.WORKLOAD_KINDS


def test_api_sweep_cache_canonicalizes_policy_spellings(tmp_path, monkeypatch):
    cache = str(tmp_path / "cache.json")
    api.sweep([W_SMALL], ["GreedyP */OPT=MIN"], cache_path=cache, n_workers=1)

    import repro.sched.sweep as sweep_mod
    monkeypatch.setattr(
        sweep_mod, "run_grid",
        lambda *a, **kw: pytest.fail("equivalent spelling missed the cache"))
    warm = api.sweep([W_SMALL], ["greedyp */opt=min"], cache_path=cache,
                     n_workers=1)
    # served from cache, reported under the spelling this caller asked for
    assert warm.records[0]["policy"] == "greedyp */opt=min"
    assert warm.filter(policy="greedyp */opt=min")


# --------------------------------------------------------------------------- #
# atomic sweep artifacts                                                       #
# --------------------------------------------------------------------------- #
def test_save_json_creates_parents_atomically(tmp_path):
    res = api.run_grid(api.grid([W_SMALL], ["FCFS"]), n_workers=1)
    path = str(tmp_path / "deep" / "nested" / "sweep.json")
    out = res.save_json(path)
    assert out == path and os.path.exists(path)
    assert json.loads(open(path).read())["n_cells"] == 1
    leftovers = [f for f in os.listdir(os.path.dirname(path))
                 if ".tmp." in f]
    assert not leftovers          # tmp file renamed away, never left behind


# --------------------------------------------------------------------------- #
# deprecation shims                                                            #
# --------------------------------------------------------------------------- #
def _deprecations(record):
    return [w for w in record
            if issubclass(w.category, DeprecationWarning)
            and "repro.api" in str(w.message)]


def test_legacy_entry_points_warn_exactly_once():
    from repro.sched.batch import batch_schedule
    from repro.sched.simulator import DFRSSimulator, simulate

    specs = make_trace(W_SMALL)
    _compat.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        simulate(specs, "FCFS", SimParams(n_nodes=16))
        simulate(specs, "EASY", SimParams(n_nodes=16))
    assert len(_deprecations(rec)) == 1

    _compat.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        batch_schedule(specs, "FCFS", SimParams(n_nodes=16))
        batch_schedule(specs, "EASY", SimParams(n_nodes=16))
    assert len(_deprecations(rec)) == 1

    _compat.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        DFRSSimulator(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=16))
        DFRSSimulator(specs, "GreedyP */OPT=MIN", SimParams(n_nodes=16))
    assert len(_deprecations(rec)) == 1


def test_api_entry_points_do_not_warn():
    _compat.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        api.simulate(W_SMALL, "FCFS")
    assert not _deprecations(rec)


# --------------------------------------------------------------------------- #
# CLI                                                                          #
# --------------------------------------------------------------------------- #
def test_cli_policies(capsys):
    assert cli_main(["policies"]) == 0
    out = capsys.readouterr().out
    assert "GreedyPM */per/OPT=MIN" in out
    assert "116 combinations" in out
    assert "EASY+OPT=MIN" in out
    assert "fcfs-queue" in out


def test_cli_policies_json(capsys):
    assert cli_main(["policies", "--all", "--json"]) == 0
    info = json.loads(capsys.readouterr().out)
    assert len(info["paper_space"]) == 116


def test_cli_scenarios(capsys):
    assert cli_main(["scenarios"]) == 0
    out = capsys.readouterr().out
    assert "baseline" in out.split() and "rack_failure" in out.split()
    # one-line builder docstrings surface in the human-readable listing
    assert "Unperturbed cell" in out
    assert "rack_failure+arrival_burst" in out     # chain grammar hint


def test_cli_scenarios_json(capsys):
    assert cli_main(["scenarios", "--json"]) == 0
    docs = json.loads(capsys.readouterr().out)
    assert set(docs["trace"]) == set(api.list_scenarios())
    assert set(docs["reactive"]) == set(api.list_reactive())
    assert all(isinstance(d, str) and d
               for part in docs.values() for d in part.values())


def test_cli_workloads(capsys):
    assert cli_main(["workloads"]) == 0
    out = capsys.readouterr().out
    for token in ("lublin", "hpc2n", "swf:<path>", "tpu"):
        assert token in out
    assert cli_main(["workloads", "--json"]) == 0
    info = json.loads(capsys.readouterr().out)
    assert info["swf"]["required"] == ["path"]
    assert info["lublin"]["supports_load"] and not info["hpc2n"]["supports_load"]


def test_cli_trace_smoke_fingerprints_stable(capsys):
    mini = os.path.join(os.path.dirname(__file__), "data", "mini.swf")
    argv = ["trace-smoke", "--jobs", "15", "--nodes", "16", "--swf", mini]
    assert cli_main(argv) == 0
    first = json.loads(capsys.readouterr().out)
    assert cli_main(argv) == 0
    second = json.loads(capsys.readouterr().out)
    assert first == second             # deterministic fingerprints
    kinds = {k.split("-")[0] for k in first}
    assert {"lublin", "hpc2n", "swf", "tpu"} <= kinds
    # the composed chain is part of the smoke surface
    assert any("rack_failure+arrival_burst" in k for k in first)


def test_cli_simulate(capsys):
    assert cli_main([
        "simulate", "--policy", "GreedyP */OPT=MIN",
        "--workload", "lublin", "--jobs", "25", "--nodes", "16",
        "--bound"]) == 0
    out = capsys.readouterr().out
    assert "max bounded stretch" in out and "Theorem-1 lower bound" in out


def test_cli_simulate_json_roundtrips(capsys):
    assert cli_main([
        "simulate", "--policy", "EASY+OPT=MIN", "--workload", "lublin",
        "--jobs", "20", "--nodes", "16", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["policy"] == "EASY+OPT=MIN"
    assert len(payload["completions"]) == 20


def test_cli_sweep_with_cache(tmp_path, capsys):
    out_json = str(tmp_path / "sweep.json")
    cache = str(tmp_path / "cache.json")
    argv = ["sweep", "--policies", "FCFS,EASY+OPT=MIN",
            "--workload", "lublin", "--jobs", "20", "--nodes", "16",
            "--seeds", "0,1", "--out", out_json, "--cache", cache]
    assert cli_main(argv) == 0
    first = capsys.readouterr().out
    assert "4 cells" in first
    art = json.loads(open(out_json).read())
    assert art["n_cells"] == 4
    assert {r["policy"] for r in art["records"]} == {"FCFS", "EASY+OPT=MIN"}
    # resumed run serves everything from the cache
    assert cli_main(argv) == 0
    assert "4 cells" in capsys.readouterr().out
    assert json.loads(open(cache).read())["n_records"] == 4


def test_cli_sweep_requires_policies(capsys):
    assert cli_main(["sweep", "--workload", "lublin"]) == 2


def test_cli_rejects_empty_seeds(capsys):
    with pytest.raises(SystemExit) as exc:
        cli_main(["simulate", "--policy", "FCFS", "--seeds", ","])
    assert exc.value.code == 2
    assert "no seeds" in capsys.readouterr().err


def test_record_cache_accepts_one_pass_iterables():
    from repro.sched.sweep import RecordCache
    recs = RecordCache().sweep(
        (w for w in [W_SMALL]), iter(["FCFS"]),
        periods=iter([600.0, 1200.0]), n_workers=1, compute_bound=False)
    assert len(recs) == 2             # generator inputs must not truncate


def test_cli_simulate_rejects_multiple_seeds(capsys):
    assert cli_main(["simulate", "--policy", "FCFS",
                     "--seeds", "0,1,2"]) == 2
    assert "one cell" in capsys.readouterr().err


def test_cli_rejects_invalid_loads(capsys):
    with pytest.raises(SystemExit) as exc:
        cli_main(["simulate", "--policy", "FCFS", "--workload", "hpc2n",
                  "--loads", "0.7"])
    assert exc.value.code == 2
    assert "lublin" in capsys.readouterr().err


def test_cli_rejects_unknown_workload_kind(capsys):
    with pytest.raises(SystemExit) as exc:
        cli_main(["simulate", "--policy", "FCFS", "--workload", "marsaglia"])
    assert exc.value.code == 2
    assert "unknown workload kind" in capsys.readouterr().err


def test_cli_simulate_swf_workload_and_chained_scenario(capsys):
    mini = os.path.join(os.path.dirname(__file__), "data", "mini.swf")
    assert cli_main([
        "simulate", "--policy", "EASY", "--workload", f"swf:{mini}",
        "--jobs", "0", "--nodes", "128",
        "--scenario", "rack_failure+arrival_burst", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert len(payload["completions"]) == 10


def test_cli_sweep_swf_and_chain_resumes_from_cache(tmp_path, capsys):
    """The acceptance path: a sweep grid including an swf: workload and a
    composed scenario runs end to end, and the resumed run is served
    entirely from the fingerprint-keyed cache."""
    mini = os.path.join(os.path.dirname(__file__), "data", "mini.swf")
    cache = str(tmp_path / "cache.json")
    argv = ["sweep", "--policies", "FCFS,GreedyP */OPT=MIN",
            "--workload", f"swf:{mini}", "--jobs", "0", "--nodes", "128",
            "--scenarios", "baseline,rack_failure+arrival_burst",
            "--cache", cache]
    assert cli_main(argv) == 0
    assert "4 cells" in capsys.readouterr().out
    payload = json.loads(open(cache).read())
    assert payload["n_records"] == 4
    assert all(r["trace_fingerprint"] for r in payload["records"])

    import repro.sched.sweep as sweep_mod
    orig = sweep_mod.run_grid
    sweep_mod.run_grid = lambda *a, **kw: pytest.fail("resume missed cache")
    try:
        assert cli_main(argv) == 0
    finally:
        sweep_mod.run_grid = orig
    assert "4 cells" in capsys.readouterr().out


def test_record_cache_checkpoints_mid_batch(tmp_path, monkeypatch):
    """With a disk path, a sweep interrupted mid-batch keeps the chunks
    already simulated — the re-run resumes instead of starting over."""
    from repro.sched.sweep import RecordCache
    import repro.sched.sweep as sweep_mod

    cache_path = str(tmp_path / "c.json")
    workloads = [WorkloadSpec("lublin", n_jobs=15, n_nodes=16, seed=s)
                 for s in range(3)]
    orig = sweep_mod.run_grid
    calls = []

    def failing_second_chunk(cells, **kw):
        calls.append(len(cells))
        if len(calls) == 2:
            raise KeyboardInterrupt("simulated ctrl-c mid-sweep")
        return orig(cells, **kw)

    # chunk size floor is max(4*n_workers, 8) = 8 -> 9 cells = 2 chunks
    monkeypatch.setattr(sweep_mod, "run_grid", failing_second_chunk)
    with pytest.raises(KeyboardInterrupt):
        RecordCache(cache_path).sweep(
            workloads, ["FCFS", "EASY", "GreedyP */OPT=MIN"],
            n_workers=1, compute_bound=False)
    assert len(json.loads(open(cache_path).read())["records"]) == 8

    monkeypatch.setattr(sweep_mod, "run_grid", orig)
    resumed = RecordCache(cache_path)
    assert len(resumed) == 8          # first chunk survived the interrupt
    recs = resumed.sweep(workloads, ["FCFS", "EASY", "GreedyP */OPT=MIN"],
                         n_workers=1, compute_bound=False)
    assert len(recs) == 9             # only the last cell was re-simulated


def test_resumed_sweep_grows_artifact_with_unique_cells(tmp_path):
    cache = str(tmp_path / "c.json")
    api.sweep([W_SMALL], ["FCFS", "EASY"], cache_path=cache, n_workers=1)
    grown = api.sweep([W_SMALL], ["FCFS", "EASY", "GreedyP */OPT=MIN"],
                      cache_path=cache, n_workers=1,
                      json_path=str(tmp_path / "art.json"))
    art = json.loads(open(tmp_path / "art.json").read())
    cells = [r["cell"] for r in art["records"]]
    assert cells == [0, 1, 2]         # want-order, no stale/colliding ids
    assert len(grown.filter(policy="GreedyP */OPT=MIN")) == 1
