"""Shared test helpers."""
import dataclasses


def result_dict(r):
    """SimResult fields minus sim_wall_s (a wall-clock measurement, not a
    simulation outcome — bit-identity comparisons are over the outcome
    fields only)."""
    d = dataclasses.asdict(r)
    d.pop("sim_wall_s")
    return d
