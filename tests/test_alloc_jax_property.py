"""Property tests: the JAX water-filling is the numpy kernel, bit for bit.

Hypothesis drives the same instance space as ``test_alloc_property``
(zero-need jobs, dead nodes, node multiplicities, empty running sets) and
asserts exact equality — not approximate closeness — between
``alloc_jax.maxmin_yields_jax`` (x64, adds-only matvec) and
``maxmin_yields_csr``, plus a padded-batch property proving that padding
rows/columns/lanes never perturbs any real lane.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
pytest.importorskip("jax")

from hypothesis import given, settings, strategies as st

from repro.core import alloc_jax
from repro.core.alloc_kernels import build_csr, maxmin_yields_csr

pytestmark = pytest.mark.skipif(not alloc_jax.has_jax(),
                                reason="jax present but not importable")


@st.composite
def csr_instances(draw, max_width=24, max_nodes=10):
    W = draw(st.integers(0, max_width))
    N = draw(st.integers(1, max_nodes))
    cpu = draw(st.lists(st.sampled_from([0.0, 0.2, 0.5, 0.75, 1.0]),
                        min_size=W, max_size=W))
    running = draw(st.lists(st.booleans(), min_size=W, max_size=W))
    mappings = []
    for j in range(W):
        if running[j]:
            mappings.append(draw(st.lists(st.integers(0, N - 1),
                                          min_size=1, max_size=4)))
        else:
            mappings.append([])
    inc = build_csr(cpu, mappings, N)
    active = np.array(running, dtype=bool)
    return inc, active


@settings(max_examples=40, deadline=None)
@given(csr_instances())
def test_maxmin_jax_bit_equal(inst):
    inc, active = inst
    got = alloc_jax.maxmin_yields_jax(inc, active)
    ref = maxmin_yields_csr(inc, active)
    assert got.dtype == ref.dtype == np.float64
    assert np.array_equal(got, ref)


@settings(max_examples=15, deadline=None)
@given(st.lists(csr_instances(max_width=12, max_nodes=6),
                min_size=1, max_size=5),
       st.integers(0, 3), st.integers(0, 4), st.integers(0, 2))
def test_padded_batch_bit_equal(insts, pad_n, pad_w, pad_lanes):
    incs = [i for i, _ in insts]
    actives = [a for _, a in insts]
    present, weight, active = alloc_jax.pad_batch(
        incs, actives,
        n_nodes=max(i.n_nodes for i in incs) + pad_n,
        width=max(max(i.width for i in incs), 1) + pad_w,
        n_lanes=len(incs) + pad_lanes)
    y = alloc_jax.maxmin_yields_batch(present, weight, active)
    for b, (inc, act) in enumerate(insts):
        assert np.array_equal(y[b, : inc.width], maxmin_yields_csr(inc, act))
        assert not y[b, inc.width:].any()
    assert not y[len(insts):].any()
