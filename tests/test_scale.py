"""Million-job-trace machinery: streaming ingest, compaction, O(active).

Three families:

* structural — geometric capacity growth under online submission, the
  release-window chunk partition, the sorted-log requirement of the
  streaming swf parser, retired-row accounting after compaction;
* bit-identity — streamed + compacted runs (and light results, and
  snapshot/restore across a compaction) must reproduce the upfront,
  never-compacted oracle *exactly*, including on the 17-cell golden
  acceptance grid;
* memory — a 10^5-job swf log streamed through a compacting session must
  complete with tracemalloc-observed peak allocation bounded by the
  active set, not the total job count.
"""
import dataclasses
import tracemalloc

from conftest import result_dict

import numpy as np
import pytest

from repro.core.job import JobSpec
from repro.sched.engine import Engine, SimParams
from repro.sched.scenarios import apply_scenario
from repro.sched.session import SNAPSHOT_VERSION, SimSession, open_session
from repro.workloads.hpc2n import NODE_MEM_GB, iter_swf_windows
from repro.workloads.registry import (WorkloadSpec, make_trace,
                                      make_trace_ir, stream_trace)
from repro.workloads.trace import Trace


# --------------------------------------------------------------------------- #
# helpers                                                                      #
# --------------------------------------------------------------------------- #
def synthetic_swf_lines(n_jobs, seed=0, mean_gap=800.0):
    """Deterministic submit-sorted swf rows (stable ~0.5 offered load on
    64 nodes after the §5.3.1 preprocessing)."""
    rng = np.random.default_rng(seed)
    node_kb = NODE_MEM_GB * 1024 * 1024
    t = 0.0
    for j in range(n_jobs):
        t += float(rng.exponential(mean_gap))
        f = ["-1"] * 18
        f[0] = str(j + 1)
        f[1] = f"{t:.1f}"
        f[3] = f"{rng.uniform(60.0, 6000.0):.1f}"
        f[4] = str(int(rng.integers(1, 33)))
        f[6] = f"{rng.uniform(0.05, 0.45) * node_kb:.0f}"
        yield " ".join(f)


def write_swf(path, n_jobs, seed=0, **kw):
    with open(path, "w") as fh:
        fh.write("; synthetic test log\n")
        for line in synthetic_swf_lines(n_jobs, seed=seed, **kw):
            fh.write(line + "\n")
    return str(path)


# --------------------------------------------------------------------------- #
# structural: geometric growth, chunk partition, parser contracts              #
# --------------------------------------------------------------------------- #
def test_extend_growth_is_geometric_not_quadratic():
    """10k one-job online batches must trigger O(log n) reallocations."""
    eng = Engine((), "FCFS", SimParams(n_nodes=8))
    st = eng.state
    n = 10_000
    for j in range(n):
        st.extend([JobSpec(jid=j, release=float(j), proc_time=1.0,
                           n_tasks=1, cpu_need=0.5, mem_req=0.1)])
    assert len(st.specs) == n
    assert st.n_total == n
    assert st.capacity >= n
    # doubling from 16: ceil(log2(10000/16)) + 1 = 11 grows; quadratic
    # (grow-by-one) would be ~10k
    assert st.grow_count <= 2 * int(np.ceil(np.log2(n))) + 2
    assert (st.gidx == np.arange(n)).all()
    assert (st.status == 0).all()  # S_NOT_ARRIVED


def test_iter_chunks_partitions_sorted_trace():
    tr = make_trace_ir(WorkloadSpec("lublin", n_jobs=500, n_nodes=32, seed=4))
    srt = tr.sorted_by_release()
    lo = float(srt.release[0])
    window = max((float(srt.release[-1]) - lo) / 13.0, 1.0)
    chunks = list(tr.iter_chunks(window))
    assert all(len(c) for c in chunks)
    off = 0
    for c in chunks:
        # contiguous slice of the sorted trace
        for name in ("jid", "release", "proc_time", "n_tasks",
                     "cpu_need", "mem_req"):
            assert (getattr(srt, name)[off:off + len(c)]
                    == getattr(c, name)).all()
        # all releases inside one window
        k = np.floor((c.release - lo) / window)
        assert (k == k[0]).all()
        off += len(c)
    assert off == len(srt)
    with pytest.raises(ValueError):
        next(tr.iter_chunks(0.0))


def test_iter_swf_windows_matches_whole_log_parse(tmp_path):
    from repro.workloads.hpc2n import hpc2n_preprocess, parse_swf

    path = write_swf(tmp_path / "log.swf", 400, seed=1)
    whole = hpc2n_preprocess(parse_swf(path))
    streamed = [s for chunk in iter_swf_windows(path, 43_200.0)
                for s in chunk]
    assert streamed == whole
    # n_jobs caps the prefix by accepted rows, matching the swf kind
    capped = [s for chunk in iter_swf_windows(path, 43_200.0, n_jobs=111)
              for s in chunk]
    assert capped == whole[:111]


def test_iter_swf_windows_rejects_unsorted_log(tmp_path):
    lines = list(synthetic_swf_lines(50, seed=2))
    lines[10], lines[30] = lines[30], lines[10]
    path = tmp_path / "unsorted.swf"
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(ValueError, match="not sorted"):
        for _ in iter_swf_windows(str(path), 3600.0):
            pass


def test_swf_stream_kind_matches_swf_kind(tmp_path):
    path = write_swf(tmp_path / "log.swf", 400, seed=3)
    w_mat = WorkloadSpec("swf", n_jobs=0, n_nodes=24, params={"path": path})
    w_str = WorkloadSpec("swf-stream", n_jobs=0, n_nodes=24,
                         params={"path": path, "window": 43_200.0})
    ref = make_trace_ir(w_mat)
    # materialized fallback of the streaming kind is row-identical
    assert make_trace_ir(w_str).fingerprint == ref.fingerprint
    # chunk concatenation reproduces the sorted materialized trace
    srt = ref.sorted_by_release()
    off = 0
    for c in stream_trace(w_str):
        for name in ("jid", "release", "proc_time", "n_tasks",
                     "cpu_need", "mem_req"):
            assert (getattr(srt, name)[off:off + len(c)]
                    == getattr(c, name)).all()
        off += len(c)
    assert off == len(srt)


def test_compaction_evicts_rows_and_preserves_accounting():
    tr = make_trace_ir(WorkloadSpec("lublin", n_jobs=200, n_nodes=32, seed=5))
    ses = open_session(SimParams(n_nodes=32), "EASY")
    ses.submit(tr)
    ses.run_to_exhaustion()
    st = ses.engine.state
    assert len(st.specs) == 200
    evicted = ses.compact()
    assert evicted == 200
    assert len(st.specs) == 0
    assert len(st.retired) == 200
    assert st.n_total == 200
    assert ses.compact() == 0  # idempotent once empty
    obs = ses.observe()
    assert obs["n_completed"] == 200
    # duplicate jids are still rejected after their rows were evicted
    with pytest.raises(ValueError):
        ses.submit([JobSpec(jid=int(tr.jid[0]), release=st.now + 1.0,
                            proc_time=1.0, n_tasks=1, cpu_need=0.5,
                            mem_req=0.1)])


# --------------------------------------------------------------------------- #
# bit-identity: streamed + compacted == upfront oracle                         #
# --------------------------------------------------------------------------- #
GOLDEN_POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
                   "GreedyPM */per/OPT=MIN/MINVT=600"]
GOLDEN_WORKLOADS = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
                    WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]
GOLDEN_CASES = [(w, p, sc)
                for w in GOLDEN_WORKLOADS
                for p in GOLDEN_POLICIES
                for sc in ("baseline", "rack_failure")]
GOLDEN_CASES.append((GOLDEN_WORKLOADS[0], "/stretch-per/OPT=MAX", "baseline"))


@pytest.mark.parametrize(
    "workload,policy,scenario", GOLDEN_CASES,
    ids=[f"{w.name}-{p}-{sc}" for w, p, sc in GOLDEN_CASES])
def test_golden_compacted_streamed_equals_upfront(workload, policy, scenario):
    """The 17-cell acceptance grid: submit-everything + never-compact vs
    stream-in-chunks + compact-aggressively, SimResults exactly equal."""
    specs = make_trace(workload)
    specs, events = apply_scenario(scenario, specs, workload.n_nodes,
                                   seed=workload.seed)
    params = SimParams(n_nodes=workload.n_nodes)
    ref = Engine(specs, policy, params, cluster_events=events).run()

    tr = Trace.from_specs(specs)
    lo, span = tr.span()
    ses = open_session(
        SimParams(n_nodes=workload.n_nodes, compact_interval=8), policy,
        cluster_events=events)
    ses.stream(tr.iter_chunks(span / 7.0))
    got = ses.result()
    assert result_dict(got) == result_dict(ref)


def test_light_result_matches_full_aggregates():
    tr = make_trace_ir(WorkloadSpec("lublin", n_jobs=300, n_nodes=32, seed=6))
    ses = open_session(SimParams(n_nodes=32, compact_interval=64),
                       "GreedyP */OPT=MIN")
    ses.submit(tr)
    ses.run_to_exhaustion()
    full = result_dict(ses.result())
    light = result_dict(ses.result(light=True))
    assert light.pop("completions") == {}
    assert light.pop("stretches") == {}
    full.pop("completions"), full.pop("stretches")
    assert light == full


def test_snapshot_restore_across_compaction(tmp_path):
    assert SNAPSHOT_VERSION == 3
    tr = make_trace_ir(WorkloadSpec("lublin", n_jobs=200, n_nodes=32, seed=7))
    params = SimParams(n_nodes=32, compact_interval=25)
    ses = open_session(params, "GreedyPM *")
    ses.submit(tr)
    ses.step_until(float(np.sort(np.asarray(tr.release))[100]))
    ses.compact()
    assert len(ses.engine.state.retired) > 0

    path = str(tmp_path / "snap.json")
    ses.snapshot().save(path)
    resumed = SimSession.restore(path)
    r_resumed = resumed.run_to_exhaustion().result()
    r_cont = ses.run_to_exhaustion().result()
    assert result_dict(r_resumed) == result_dict(r_cont)

    # and both equal the never-compacted oracle
    oracle = open_session(SimParams(n_nodes=32), "GreedyPM *")
    oracle.submit(tr)
    assert result_dict(oracle.run()) == result_dict(r_cont)


def test_streamed_swf_session_equals_upfront(tmp_path):
    path = write_swf(tmp_path / "log.swf", 600, seed=8)
    w_mat = WorkloadSpec("swf", n_jobs=0, n_nodes=48, params={"path": path})
    w_str = WorkloadSpec("swf-stream", n_jobs=0, n_nodes=48,
                         params={"path": path, "window": 86_400.0})
    ref = open_session(SimParams(n_nodes=48), "EASY")
    ref.submit(make_trace_ir(w_mat))
    r_ref = ref.run()
    ses = open_session(SimParams(n_nodes=48, compact_interval=100), "EASY")
    ses.stream(stream_trace(w_str))
    assert result_dict(ses.result()) == result_dict(r_ref)


# --------------------------------------------------------------------------- #
# memory: 10^5-job streaming run, allocation bounded by the active set         #
# --------------------------------------------------------------------------- #
def test_streaming_1e5_jobs_bounded_memory(tmp_path):
    n = 100_000
    path = write_swf(tmp_path / "big.swf", n, seed=9)
    wspec = WorkloadSpec("swf-stream", n_jobs=0, n_nodes=64,
                         params={"path": path, "window": 4 * 86_400.0})
    ses = open_session(SimParams(n_nodes=64, compact_interval=4096), "FCFS")
    st = ses.engine.state
    peak_cap = 0

    def watched():
        nonlocal peak_cap
        for ch in stream_trace(wspec):
            peak_cap = max(peak_cap, st.capacity)
            yield ch

    tracemalloc.start(1)
    try:
        base = tracemalloc.get_traced_memory()[0]
        ses.stream(watched())
        peak = tracemalloc.get_traced_memory()[1]
    finally:
        tracemalloc.stop()
    peak_cap = max(peak_cap, st.capacity)

    r = ses.result(light=True)
    assert st.n_total == n
    assert len(st.specs) == 0
    assert len(st.retired) == n
    assert dataclasses.asdict(r)["completions"] == {}
    # n arrivals + n completions (+ possibly one exhaustion peek), minus
    # the few completions whose projected timestamps round together at
    # large simulated time (>4e6 s) and batch into one loop iteration
    assert 2 * n - n // 100 <= r.events <= 2 * n + 1
    # row capacity stays bounded by active set + compaction lag, never
    # approaching the total job count
    assert peak_cap < n // 4, peak_cap
    # allocation ceiling: O(active) engine + O(n) retired log columns
    # (~5 MB here) stay far below the ~60 MB an uncompacted SoA + views +
    # specs footprint reaches at this scale
    assert peak - base < 40 * 1024 * 1024, (peak - base, base)
